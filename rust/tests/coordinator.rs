//! Integration tests over the full L3 coordinator (requires artifacts).

use sigma_moe::coordinator::{Checkpoint, Trainer};
use sigma_moe::data;
use sigma_moe::runtime::{Client, ModelBundle};
use sigma_moe::serving::{Engine, GenRequest, Sampler};

fn bundle_for(preset: &str) -> Option<(Client, ModelBundle)> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts")
        .join(preset);
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts for {preset} not built");
        return None;
    }
    let client = Client::cpu().expect("pjrt client");
    let bundle = ModelBundle::load(&client, &dir).expect("bundle");
    Some((client, bundle))
}

#[test]
fn trainer_reduces_loss_on_synthetic_corpus() {
    let Some((_c, bundle)) = bundle_for("tiny-moe") else { return };
    let m = &bundle.manifest;
    let mut trainer = Trainer::new(&bundle, 42).expect("trainer");
    let mut batcher = data::batcher_for(
        "wikitext", m.model.vocab_size, m.batch_size, m.model.context, 42)
        .unwrap();
    let outs = trainer.train(&mut batcher, 30, |_| {}).expect("train");
    let first: f32 = outs[..5].iter().map(|o| o.loss).sum::<f32>() / 5.0;
    let last: f32 = outs[outs.len() - 5..].iter().map(|o| o.loss).sum::<f32>()
        / 5.0;
    assert!(
        last < first - 0.2,
        "loss did not improve: {first} -> {last}"
    );
    // stats present for a MoE model
    assert!(outs[0].stats.keys().any(|k| k.ends_with("usage")));
}

#[test]
fn evaluate_carries_memory_and_counts_tokens() {
    let Some((_c, bundle)) = bundle_for("tiny-moe") else { return };
    let m = &bundle.manifest;
    let mut trainer = Trainer::new(&bundle, 1).expect("trainer");
    let mut batcher = data::batcher_for(
        "wikitext", m.model.vocab_size, m.batch_size, m.model.context, 9)
        .unwrap();
    let ev = trainer.evaluate(&mut batcher, 3).expect("eval");
    let expected = (3 * m.batch_size * m.model.context) as f64;
    assert_eq!(ev.token_count, expected);
    assert!(ev.nll > 0.0 && ev.nll.is_finite());
    assert!(ev.perplexity() > 1.0);
}

#[test]
fn checkpoint_roundtrip_preserves_eval() {
    let Some((_c, bundle)) = bundle_for("tiny-moe") else { return };
    let m = &bundle.manifest;
    let mut trainer = Trainer::new(&bundle, 5).expect("trainer");
    let mut batcher = data::batcher_for(
        "wikitext", m.model.vocab_size, m.batch_size, m.model.context, 5)
        .unwrap();
    trainer.train(&mut batcher, 5, |_| {}).unwrap();

    let dir = std::env::temp_dir().join("sigma_moe_it");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("it.ckpt");
    Checkpoint::from_trainer(&mut trainer, "tiny-moe")
        .unwrap()
        .save(&path)
        .unwrap();

    // evaluate original
    let mut eb = data::batcher_for(
        "wikitext", m.model.vocab_size, m.batch_size, m.model.context, 77)
        .unwrap();
    let ev1 = trainer.evaluate(&mut eb, 2).unwrap();

    // fresh trainer restored from checkpoint must match exactly
    let mut t2 = Trainer::new(&bundle, 999).expect("trainer2");
    let ck = Checkpoint::load(&path).unwrap();
    assert_eq!(ck.step, trainer.step);
    t2.restore(&ck.params, &ck.opt, ck.step).unwrap();
    let mut eb2 = data::batcher_for(
        "wikitext", m.model.vocab_size, m.batch_size, m.model.context, 77)
        .unwrap();
    let ev2 = t2.evaluate(&mut eb2, 2).unwrap();
    assert!(
        (ev1.nll - ev2.nll).abs() < 1e-5,
        "restored eval differs: {} vs {}",
        ev1.nll,
        ev2.nll
    );
}

#[test]
fn engine_generates_and_batches() {
    let Some((_c, bundle)) = bundle_for("tiny-moe") else { return };
    let m = &bundle.manifest;
    // fresh init params straight from the init program
    let init = bundle.program("init").unwrap();
    let out = init
        .run(&[sigma_moe::tensor::HostTensor::scalar_u32(1)])
        .unwrap();
    let params: Vec<(String, sigma_moe::tensor::HostTensor)> = init
        .spec
        .outputs
        .iter()
        .map(|b| b.name.clone())
        .zip(out)
        .collect();
    let mut engine = Engine::new(&bundle, &params, 3).expect("engine");
    assert_eq!(engine.n_lanes(), m.serve_batch);

    // oversubscribe the lanes to exercise queueing + continuous batching
    let n_req = engine.n_lanes() * 2 + 1;
    let mut rxs = Vec::new();
    for i in 0..n_req {
        rxs.push(engine.submit(GenRequest {
            prompt: vec![1 + i as i32, 2, 3],
            max_new_tokens: 4 + (i % 3),
            sampler: Sampler::greedy(),
            ..Default::default()
        }));
    }
    let results = engine.run_to_completion(rxs).expect("generate");
    assert_eq!(results.len(), n_req);
    for (i, r) in results.iter().enumerate() {
        assert_eq!(r.tokens.len(), 4 + (i % 3));
        for &t in &r.tokens {
            assert!((0..m.model.vocab_size as i32).contains(&t));
        }
    }
    // greedy sampling + same prompt => identical generations
    let rx_a = engine.submit(GenRequest {
        prompt: vec![5, 6, 7],
        max_new_tokens: 6,
        sampler: Sampler::greedy(),
        ..Default::default()
    });
    let rx_b = engine.submit(GenRequest {
        prompt: vec![5, 6, 7],
        max_new_tokens: 6,
        sampler: Sampler::greedy(),
        ..Default::default()
    });
    let pair = engine.run_to_completion(vec![rx_a, rx_b]).unwrap();
    assert_eq!(pair[0].tokens, pair[1].tokens,
               "greedy generation not deterministic across lanes");
}

#[test]
fn engine_admission_is_fifo_and_resets_lane_memory() {
    let Some((_c, bundle)) = bundle_for("tiny-moe") else { return };
    let init = bundle.program("init").unwrap();
    let out = init
        .run(&[sigma_moe::tensor::HostTensor::scalar_u32(2)])
        .unwrap();
    let params: Vec<(String, sigma_moe::tensor::HostTensor)> = init
        .spec
        .outputs
        .iter()
        .map(|b| b.name.clone())
        .zip(out)
        .collect();
    let mut engine = Engine::new(&bundle, &params, 11).expect("engine");
    let n_lanes = engine.n_lanes();

    // 1) FIFO admission: oversubscribe with identical prompt/budget
    // shapes. The first `n_lanes` submissions are admitted on the first
    // pump; every later submission must wait at least one full
    // generation, so its queue time strictly dominates the first wave's.
    let n_req = n_lanes * 2;
    let mut rxs = Vec::new();
    for i in 0..n_req {
        rxs.push(engine.submit(GenRequest {
            prompt: vec![1 + i as i32, 2, 3],
            max_new_tokens: 4,
            sampler: Sampler::greedy(),
            ..Default::default()
        }));
    }
    let waves = engine.run_to_completion(rxs).unwrap();
    let max_first_wave = waves[..n_lanes]
        .iter()
        .map(|r| r.queue_time)
        .max()
        .unwrap();
    for (i, r) in waves[n_lanes..].iter().enumerate() {
        assert!(
            r.queue_time >= max_first_wave,
            "request {} (second wave) queued {:?} < first wave max {:?} — \
             admission not FIFO",
            n_lanes + i,
            r.queue_time,
            max_first_wave
        );
    }

    // reference generation on a quiet engine for the memory-reset check
    let reference = engine.submit(GenRequest {
        prompt: vec![5, 6, 7],
        max_new_tokens: 6,
        sampler: Sampler::greedy(),
        ..Default::default()
    });
    let first_wave = engine.run_to_completion(vec![reference]).unwrap();

    // 2) Lane-memory reset on admit: the same greedy request run again —
    // after other traffic polluted every lane's XL memory — must generate
    // the identical continuation, which only holds if its lane's memory
    // was zeroed on admission.
    let mut noise = Vec::new();
    for i in 0..n_lanes * 2 {
        noise.push(engine.submit(GenRequest {
            prompt: vec![9 + i as i32, 1, 4],
            max_new_tokens: 5,
            sampler: Sampler::greedy(),
            ..Default::default()
        }));
    }
    engine.run_to_completion(noise).unwrap();
    let again = engine.submit(GenRequest {
        prompt: vec![5, 6, 7],
        max_new_tokens: 6,
        sampler: Sampler::greedy(),
        ..Default::default()
    });
    let second = engine.run_to_completion(vec![again]).unwrap();
    assert_eq!(
        first_wave[0].tokens, second[0].tokens,
        "greedy generation changed after lane reuse — lane memory not reset"
    );
}

#[test]
fn chunked_prefill_matches_single_token_on_device() {
    // the real-device logits comparison for chunked prefill: the same
    // greedy requests — ragged prompt lengths straddling the chunk
    // boundary (C-1, C, C+1, 2C+3) — run through (a) an engine with
    // the AOT'd `prefill` program and (b) an engine loaded *without*
    // it (the validated single-token fallback).  Greedy sampling makes
    // token equality a logits comparison at every sampled position;
    // memory equivalence follows because each later token is sampled
    // from logits conditioned on the updated memory.
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts")
        .join("tiny-moe");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts for tiny-moe not built");
        return;
    }
    let client = Client::cpu().expect("pjrt client");
    let manifest =
        sigma_moe::runtime::Manifest::load(&dir).expect("manifest");
    if !manifest.functions.contains_key("prefill") {
        eprintln!("skipping: artifacts predate the prefill program");
        return;
    }
    let chunk = manifest.prefill_chunk;
    assert!(chunk > 1, "manifest prefill_chunk must be > 1");
    let lens =
        [chunk - 1, chunk, chunk + 1, 2 * chunk + 3, 1, 3 * chunk];
    let run = |with_prefill: bool| -> (Vec<Vec<i32>>, u64, u64, u64) {
        let mut names = vec!["init", "step_fwd"];
        if with_prefill {
            names.push("prefill");
        }
        let bundle = ModelBundle::load_subset(&client, &dir, &names)
            .expect("bundle");
        let init = bundle.program("init").unwrap();
        let out = init
            .run(&[sigma_moe::tensor::HostTensor::scalar_u32(3)])
            .unwrap();
        let params: Vec<(String, sigma_moe::tensor::HostTensor)> = init
            .spec
            .outputs
            .iter()
            .map(|b| b.name.clone())
            .zip(out)
            .collect();
        let mut engine =
            Engine::new(&bundle, &params, 13).expect("engine");
        assert_eq!(
            engine.prefill_chunk(),
            if with_prefill { chunk } else { 1 }
        );
        let mut rxs = Vec::new();
        for (i, &len) in lens.iter().enumerate() {
            rxs.push(engine.submit(GenRequest {
                prompt: (0..len)
                    .map(|j| ((i * 31 + j * 7) % 50) as i32)
                    .collect(),
                max_new_tokens: 6,
                sampler: Sampler::greedy(),
                ..Default::default()
            }));
        }
        let results = engine.run_to_completion(rxs).expect("generate");
        (
            results.into_iter().map(|r| r.tokens).collect(),
            engine.steps_executed,
            engine.prefill_steps_device,
            engine.prefill_steps_host,
        )
    };
    let (toks_chunked, steps_c, dev_c, host_c) = run(true);
    let (toks_single, steps_s, dev_s, host_s) = run(false);
    // the two differently-compiled programs can disagree by float-
    // association noise (the jnp-level check needed rtol=2e-4), and a
    // near-tie in the top-2 logits can flip one greedy argmax, which
    // then rewrites that lane's whole tail.  A prefill wiring bug
    // (mask off-by-one, wrong memory gather) corrupts every
    // multi-token-prompt lane at once, so: at most one lane may
    // diverge, and it must be tie-shaped (nonempty or trivial shared
    // prefix is not required — the flip can hit token 0).
    let mismatched: Vec<usize> = toks_chunked
        .iter()
        .zip(&toks_single)
        .enumerate()
        .filter(|(_, (c, s))| c != s)
        .map(|(i, _)| i)
        .collect();
    assert!(
        mismatched.len() <= 1,
        "chunked prefill diverged from single-token feeding on lanes \
         {mismatched:?} (prompt lens {:?}) — more than a greedy \
         tie-flip can explain:\n  chunked: {toks_chunked:?}\n  single: \
         {toks_single:?}",
        mismatched.iter().map(|&i| lens[i]).collect::<Vec<_>>(),
    );
    assert!(dev_c > 0, "chunked engine must use the prefill program");
    assert_eq!(host_c, 0);
    assert_eq!(dev_s, 0, "fallback engine must not see the program");
    assert!(host_s > 0, "fallback must count its prompt pumps");
    assert!(
        steps_c < steps_s,
        "chunked prompts must take fewer dispatches ({steps_c} vs \
         {steps_s})"
    );
}

#[test]
fn manifest_flops_match_rust_model() {
    let Some((_c, bundle)) = bundle_for("tiny-moe") else { return };
    let m = &bundle.manifest;
    let rust = sigma_moe::flops::moe_ff(
        m.model.d_model, m.model.n_experts, m.model.group_size,
        m.model.expert_k);
    let py = m.flops.get("ff_flops_per_token").copied().unwrap();
    assert!(
        (rust.flops - py).abs() / py < 1e-9,
        "rust {} vs python {}",
        rust.flops,
        py
    );
}
