//! Multi-engine router tests over the fault-injecting mock fleet: all
//! artifact-free.  Covers exactly-once failover of in-flight work,
//! unhealthy-engine quarantine, bounded retries → 503, affinity
//! placement, per-engine `/metrics` consistency, and the mock-fleet
//! throughput-scaling row (1 vs 2 engines under an identical Poisson
//! plan).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use sigma_moe::serving::loadgen::{self, LoadgenCfg};
use sigma_moe::serving::router::{Fleet, Placement, RouterCfg};
use sigma_moe::serving::server::ServerConfig;
use sigma_moe::serving::{
    DropReason, GenRequest, MockBackend, MockFault, Policy, Sampler,
    StreamEvent,
};

const VOCAB: usize = 50;

struct TestFleet {
    fleet: Arc<Fleet>,
    shutdown: Arc<AtomicBool>,
    release: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

/// Stand up a fleet of mock engines (plus the placer) on raw threads —
/// no HTTP — with optional per-engine fault injection.
fn start_fleet(
    rcfg: RouterCfg,
    lanes: usize,
    step_delay: Duration,
    faults: Vec<Option<MockFault>>,
) -> TestFleet {
    let shutdown = Arc::new(AtomicBool::new(false));
    let release = Arc::new(AtomicBool::new(false));
    let fleet = Arc::new(Fleet::new(
        rcfg.clone(),
        64,
        Policy::Fifo,
        shutdown.clone(),
    ));
    let mut threads = Vec::new();
    for id in 0..rcfg.engines {
        let fleet = fleet.clone();
        let fault = faults.get(id).cloned().flatten();
        let release = release.clone();
        threads.push(std::thread::spawn(move || {
            let mut backend = MockBackend::new(lanes, VOCAB)
                .with_step_delay(step_delay)
                .with_stall_release(release);
            if let Some(f) = fault {
                backend = backend.with_fault(f);
            }
            // injected faults make this Err by design
            let _ = fleet.run_engine(id, &mut backend);
        }));
    }
    let placer_fleet = fleet.clone();
    threads.push(std::thread::spawn(move || placer_fleet.run_placer()));
    TestFleet { fleet, shutdown, release, threads }
}

impl TestFleet {
    fn stop(self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.release.store(true, Ordering::SeqCst);
        for t in self.threads {
            let _ = t.join();
        }
    }
}

/// Block until every engine driver has published capacity (first
/// heartbeat) so placement tests aren't skewed by thread start order.
fn wait_ready(fleet: &Fleet, engines: usize) {
    let deadline = Instant::now() + Duration::from_secs(2);
    loop {
        let doc = fleet.fleet_json();
        let rows = doc.get("engines").unwrap().as_arr().unwrap();
        let ready = rows
            .iter()
            .take(engines)
            .filter(|r| {
                r.get("free_lanes").unwrap().as_f64().unwrap() > 0.0
            })
            .count();
        if ready >= engines || Instant::now() > deadline {
            return;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
}

fn greq(prompt: Vec<i32>, max_new: usize) -> GenRequest {
    GenRequest {
        prompt,
        max_new_tokens: max_new,
        sampler: Sampler::greedy(),
        ..Default::default()
    }
}

/// Drain a request's event stream: wait for the first terminal event
/// (up to `timeout`), then linger to catch forbidden double-terminals
/// or duplicate tokens.  Returns (tokens seen, terminal events seen).
fn collect_terminal(
    rx: &mpsc::Receiver<StreamEvent>,
    timeout: Duration,
) -> (Vec<i32>, Vec<StreamEvent>) {
    let deadline = Instant::now() + timeout;
    let mut tokens = Vec::new();
    let mut terminals = Vec::new();
    while terminals.is_empty() {
        let left = deadline.saturating_duration_since(Instant::now());
        if left.is_zero() {
            return (tokens, terminals);
        }
        match rx.recv_timeout(left) {
            Ok(StreamEvent::Token(t)) => tokens.push(t),
            Ok(StreamEvent::Admitted) => {}
            Ok(ev) => terminals.push(ev),
            Err(_) => return (tokens, terminals),
        }
    }
    std::thread::sleep(Duration::from_millis(120));
    while let Ok(ev) = rx.try_recv() {
        match ev {
            StreamEvent::Token(t) => tokens.push(t),
            StreamEvent::Admitted => {}
            ev => terminals.push(ev),
        }
    }
    (tokens, terminals)
}

#[test]
fn failover_requeues_inflight_exactly_once() {
    // engine 0 wedges (stops heartbeating) after 3 pumps with several
    // requests mid-generation; every request must still complete with
    // exactly one terminal event and a continuous, duplicate-free
    // token stream (the replay suppresses already-streamed tokens and
    // the deterministic mock regenerates the identical sequence).
    let rcfg = RouterCfg {
        engines: 2,
        placement: Placement::RoundRobin,
        heartbeat_timeout: Duration::from_millis(150),
        error_threshold: 1,
        max_retries: 2,
        readmit_after: 0,
    };
    let tf = start_fleet(
        rcfg,
        2,
        Duration::from_millis(1),
        vec![Some(MockFault::StallAfter(3)), None],
    );
    wait_ready(&tf.fleet, 2);
    let mut rxs = Vec::new();
    for i in 0..8i32 {
        let (tx, rx) = mpsc::channel();
        let prompt = vec![i + 1];
        tf.fleet
            .sched()
            .enqueue(greq(prompt.clone(), 6), None, tx)
            .unwrap();
        rxs.push((prompt, rx));
    }
    for (prompt, rx) in &rxs {
        let (tokens, terminals) =
            collect_terminal(rx, Duration::from_secs(15));
        assert_eq!(
            terminals.len(),
            1,
            "exactly one terminal event for prompt {prompt:?} \
             (got {terminals:?})"
        );
        let expect: Vec<i32> = (0..6)
            .map(|k| MockBackend::expected_token(prompt, k, VOCAB))
            .collect();
        match &terminals[0] {
            StreamEvent::Done(res) => {
                assert_eq!(
                    tokens, expect,
                    "stream must be continuous and duplicate-free \
                     across the failover"
                );
                assert_eq!(res.tokens, expect);
            }
            other => panic!("prompt {prompt:?} dropped: {other:?}"),
        }
    }
    assert!(
        tf.fleet.requeues() >= 1,
        "the stalled engine held in-flight work that must be re-queued"
    );
    assert_eq!(tf.fleet.retries_exhausted(), 0);
    assert!(!tf.fleet.engine_healthy(0));
    assert!(tf.fleet.engine_healthy(1));
    assert_eq!(
        tf.fleet.engine_completions(0) + tf.fleet.engine_completions(1),
        8,
        "zero double-completions"
    );
    tf.stop();
}

#[test]
fn unhealthy_engine_receives_no_new_placements() {
    // engine 0 errors on its first pump; after the router quarantines
    // it, a whole second batch must complete with zero new placements
    // on the dead engine.
    let rcfg = RouterCfg {
        engines: 2,
        placement: Placement::RoundRobin,
        heartbeat_timeout: Duration::from_secs(5),
        error_threshold: 1,
        max_retries: 2,
        readmit_after: 0,
    };
    let tf = start_fleet(
        rcfg,
        2,
        Duration::ZERO,
        vec![Some(MockFault::ErrorAfter(0)), None],
    );
    wait_ready(&tf.fleet, 2);
    let run_batch = |n: i32, base: i32| {
        let mut rxs = Vec::new();
        for i in 0..n {
            let (tx, rx) = mpsc::channel();
            tf.fleet
                .sched()
                .enqueue(greq(vec![base + i], 4), None, tx)
                .unwrap();
            rxs.push(rx);
        }
        for rx in &rxs {
            let (_, terminals) =
                collect_terminal(rx, Duration::from_secs(15));
            assert_eq!(terminals.len(), 1);
            assert!(
                matches!(terminals[0], StreamEvent::Done(_)),
                "request must fail over and complete: {terminals:?}"
            );
        }
    };
    run_batch(4, 1);
    assert!(!tf.fleet.engine_healthy(0));
    let placements_frozen = tf.fleet.engine_placements(0);
    run_batch(4, 100);
    assert_eq!(
        tf.fleet.engine_placements(0),
        placements_frozen,
        "unhealthy engine must receive no new placements"
    );
    assert_eq!(tf.fleet.engine_completions(0), 0);
    assert_eq!(tf.fleet.engine_completions(1), 8);
    tf.stop();
}

#[test]
fn exhausted_retries_drop_with_engine_failure() {
    // a fleet of one poisoned engine with zero retries: the submitted
    // request gets exactly one Dropped(EngineFailure), and once no
    // healthy engine remains, later arrivals are failed fast too.
    let rcfg = RouterCfg {
        engines: 1,
        placement: Placement::LeastLoaded,
        heartbeat_timeout: Duration::from_secs(5),
        error_threshold: 1,
        max_retries: 0,
        readmit_after: 0,
    };
    let tf = start_fleet(
        rcfg,
        2,
        Duration::ZERO,
        vec![Some(MockFault::NanLogits)],
    );
    wait_ready(&tf.fleet, 1);
    let (tx, rx) = mpsc::channel();
    tf.fleet.sched().enqueue(greq(vec![1], 4), None, tx).unwrap();
    let (_, terminals) = collect_terminal(&rx, Duration::from_secs(15));
    assert_eq!(terminals.len(), 1);
    assert!(matches!(
        terminals[0],
        StreamEvent::Dropped(DropReason::EngineFailure)
    ));
    assert_eq!(tf.fleet.retries_exhausted(), 1);
    assert!(!tf.fleet.alive());
    let (tx2, rx2) = mpsc::channel();
    tf.fleet.sched().enqueue(greq(vec![2], 4), None, tx2).unwrap();
    let (_, terminals) = collect_terminal(&rx2, Duration::from_secs(15));
    assert_eq!(terminals.len(), 1);
    assert!(matches!(
        terminals[0],
        StreamEvent::Dropped(DropReason::EngineFailure)
    ));
    tf.stop();
}

#[test]
fn affinity_places_same_prefix_on_one_engine() {
    let rcfg = RouterCfg {
        engines: 2,
        placement: Placement::Affinity,
        heartbeat_timeout: Duration::from_secs(5),
        error_threshold: 3,
        max_retries: 1,
        readmit_after: 0,
    };
    let tf = start_fleet(rcfg, 2, Duration::ZERO, vec![None, None]);
    wait_ready(&tf.fleet, 2);
    let mut rxs = Vec::new();
    for i in 0..6i32 {
        let (tx, rx) = mpsc::channel();
        // identical 8-token affinity prefix, differing suffix
        let mut prompt = vec![5, 4, 3, 2, 1, 2, 3, 4];
        prompt.push(40 + i);
        tf.fleet.sched().enqueue(greq(prompt, 2), None, tx).unwrap();
        rxs.push(rx);
    }
    for rx in &rxs {
        let (_, terminals) = collect_terminal(rx, Duration::from_secs(15));
        assert_eq!(terminals.len(), 1);
        assert!(matches!(terminals[0], StreamEvent::Done(_)));
    }
    let (p0, p1) =
        (tf.fleet.engine_placements(0), tf.fleet.engine_placements(1));
    assert_eq!(p0 + p1, 6);
    assert!(
        p0 == 6 || p1 == 6,
        "same-prefix requests must land on one engine (got {p0}/{p1})"
    );
    tf.stop();
}

#[test]
fn recovered_stall_after_engine_rejoins_and_serves() {
    // engine 0 wedges (stops heartbeating) mid-run and is quarantined;
    // its requests fail over to engine 1.  When the wedge releases,
    // the driver's consecutive clean pumps must ride it back into the
    // placement set — no restart — and it must complete new work.
    let rcfg = RouterCfg {
        engines: 2,
        placement: Placement::RoundRobin,
        heartbeat_timeout: Duration::from_millis(120),
        error_threshold: 10, // quarantine via heartbeat, not errors
        max_retries: 2,
        readmit_after: 3,
    };
    let tf = start_fleet(
        rcfg,
        2,
        Duration::from_millis(1),
        vec![Some(MockFault::StallAfter(2)), None],
    );
    wait_ready(&tf.fleet, 2);
    let mut rxs = Vec::new();
    for i in 0..6i32 {
        let (tx, rx) = mpsc::channel();
        tf.fleet
            .sched()
            .enqueue(greq(vec![i + 1], 4), None, tx)
            .unwrap();
        rxs.push(rx);
    }
    // all requests complete on the survivor while engine 0 is wedged
    for rx in &rxs {
        let (_, terminals) =
            collect_terminal(rx, Duration::from_secs(15));
        assert_eq!(terminals.len(), 1);
        assert!(matches!(terminals[0], StreamEvent::Done(_)));
    }
    let deadline = Instant::now() + Duration::from_secs(5);
    while tf.fleet.engine_healthy(0) && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(
        !tf.fleet.engine_healthy(0),
        "wedged engine must be quarantined first"
    );
    let completions_quarantined = tf.fleet.engine_completions(0);

    // unwedge the device: one released-stall error surfaces, then the
    // backend pumps cleanly and the clean streak re-admits it
    tf.release.store(true, Ordering::SeqCst);
    let deadline = Instant::now() + Duration::from_secs(10);
    while !tf.fleet.engine_healthy(0) && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(
        tf.fleet.engine_healthy(0),
        "recovered engine must rejoin the placement set"
    );
    assert!(tf.fleet.readmissions() >= 1);

    // the re-admitted engine serves new work without a restart:
    // round-robin over a saturating batch must complete more requests
    // on engine 0 than it had while quarantined
    let mut rxs = Vec::new();
    for i in 0..8i32 {
        let (tx, rx) = mpsc::channel();
        tf.fleet
            .sched()
            .enqueue(greq(vec![100 + i], 4), None, tx)
            .unwrap();
        rxs.push(rx);
    }
    for rx in &rxs {
        let (_, terminals) =
            collect_terminal(rx, Duration::from_secs(15));
        assert_eq!(terminals.len(), 1);
        assert!(matches!(terminals[0], StreamEvent::Done(_)));
    }
    assert!(
        tf.fleet.engine_completions(0) > completions_quarantined,
        "re-admitted engine completed no new requests \
         ({} before, {} after)",
        completions_quarantined,
        tf.fleet.engine_completions(0)
    );
    tf.stop();
}

#[test]
fn metrics_per_engine_rows_sum_to_fleet_totals() {
    let cfg = LoadgenCfg {
        requests: 12,
        rps: 500.0,
        prompt_len: (2, 4),
        max_new: (3, 6),
        vocab: 64,
        stream_fraction: 0.5,
        seed: 5,
        keep_alive: true,
        timeout: Duration::from_secs(30),
        ..Default::default()
    };
    loadgen::with_mock_fleet(
        2,
        64,
        Duration::from_micros(200),
        ServerConfig::default(),
        RouterCfg {
            engines: 2,
            placement: Placement::RoundRobin,
            ..Default::default()
        },
        &[],
        |addr| {
            let row = loadgen::run(addr, &cfg, "router-metrics-test")?;
            assert_eq!(row.get("ok").unwrap().as_usize().unwrap(), 12);
            // let both drivers publish their final stats snapshots
            std::thread::sleep(Duration::from_millis(200));
            let doc = loadgen::fetch_metrics(&addr)?;
            let engines = doc.get("engines").unwrap().as_arr().unwrap();
            assert_eq!(engines.len(), 2);
            let totals = doc.get("engine").unwrap();
            for key in ["steps_executed", "tokens_generated"] {
                let sum: f64 = engines
                    .iter()
                    .map(|e| {
                        e.get("stats")
                            .unwrap()
                            .get(key)
                            .unwrap()
                            .as_f64()
                            .unwrap()
                    })
                    .sum();
                let total = totals.get(key).unwrap().as_f64().unwrap();
                assert!(
                    (sum - total).abs() < 1e-9,
                    "{key}: rows sum {sum} != fleet total {total}"
                );
                assert!(total > 0.0, "{key} must be nonzero");
            }
            let completions: f64 = engines
                .iter()
                .map(|e| {
                    e.get("completions").unwrap().as_f64().unwrap()
                })
                .sum();
            assert_eq!(completions, 12.0);
            let sched = doc.get("scheduler").unwrap();
            assert_eq!(
                sched.get("completed").unwrap().as_f64().unwrap(),
                12.0,
                "per-engine completions must equal the scheduler's"
            );
            for e in engines {
                assert!(
                    e.get("placements").unwrap().as_f64().unwrap() > 0.0,
                    "round-robin must use every engine"
                );
                assert!(e.get("healthy").unwrap().as_bool().unwrap());
            }
            let router = doc.get("router").unwrap();
            assert_eq!(
                router.get("failovers").unwrap().as_f64().unwrap(),
                0.0
            );
            assert_eq!(
                router
                    .get("healthy_engines")
                    .unwrap()
                    .as_f64()
                    .unwrap(),
                2.0
            );
            // lifecycle telemetry rides the same document: every
            // completed request fed the stage histograms, and the mock
            // backends' synthetic routers fed per-engine expert counts
            // that aggregate into the fleet rows
            let stages = doc.get("stages").unwrap();
            assert_eq!(
                stages
                    .get("queue_wait")
                    .unwrap()
                    .get("count")
                    .unwrap()
                    .as_f64()
                    .unwrap(),
                12.0
            );
            assert!(
                stages
                    .get("ttft")
                    .unwrap()
                    .get("count")
                    .unwrap()
                    .as_f64()
                    .unwrap()
                    > 0.0
            );
            let experts = doc.get("experts").unwrap();
            let fleet_tokens: f64 = experts
                .get("fleet")
                .unwrap()
                .get("layers")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|l| l.get("tokens_k").unwrap().as_f64().unwrap())
                .sum();
            assert!(fleet_tokens > 0.0, "no expert counts aggregated");
            assert_eq!(
                experts.get("engines").unwrap().as_obj().unwrap().len(),
                2,
                "both engines must report expert counts"
            );
            // every served request left a resolvable span in the ring
            let mut resolved = 0usize;
            for id in 0..32u64 {
                let (status, body) =
                    loadgen::fetch_path(&addr, &format!("/v1/trace/{id}"))?;
                if status != 200 {
                    continue;
                }
                let span = sigma_moe::json::Json::parse(&body)
                    .expect("trace json");
                if span.get("complete").unwrap().as_bool().unwrap() {
                    resolved += 1;
                }
            }
            assert_eq!(resolved, 12, "all 12 spans must resolve");
            // and the whole document round-trips through the
            // Prometheus renderer as a well-formed exposition
            let prom = loadgen::fetch_metrics_prom(&addr)?;
            sigma_moe::serving::telemetry::validate_prom(
                &prom,
                &[
                    "sigma_moe_stage_",
                    "sigma_moe_experts_",
                    "sigma_moe_engine_experts_",
                ],
            )
            .expect("fleet prom exposition");
            Ok(())
        },
    )
    .unwrap();
}

#[test]
fn mock_fleet_scaling_lifts_token_throughput() {
    // identical Poisson plan against 1 vs 2 engines whose per-pump
    // delay dominates: token throughput must scale ≥1.7x (the
    // BENCH_serve.json acceptance row; `loadgen --dry-run
    // --engines 1,2` reproduces it from the CLI).
    let cfg = LoadgenCfg {
        requests: 48,
        rps: 5000.0,
        prompt_len: (2, 4),
        max_new: (12, 12),
        vocab: 64,
        stream_fraction: 0.0,
        seed: 7,
        keep_alive: true,
        timeout: Duration::from_secs(60),
        ..Default::default()
    };
    let tput = |engines: usize| -> f64 {
        loadgen::with_mock_fleet(
            2,
            64,
            Duration::from_millis(2),
            ServerConfig { queue_cap: 256, ..Default::default() },
            RouterCfg { engines, ..Default::default() },
            &[],
            |addr| loadgen::run(addr, &cfg, "scaling"),
        )
        .unwrap()
        .get("tokens_per_sec")
        .unwrap()
        .as_f64()
        .unwrap()
    };
    let one = tput(1);
    let two = tput(2);
    assert!(
        two >= 1.7 * one,
        "2 engines {two:.0} tok/s vs 1 engine {one:.0} tok/s \
         ({:.2}x, need >= 1.7x)",
        two / one
    );
}
