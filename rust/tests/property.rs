//! Property-based tests (seeded random sweeps — the offline vendor set
//! has no proptest crate, so we drive generation with the library's own
//! PRNG): serialization round-trips, batcher/tokenizer invariants,
//! sampler and analytic-model properties.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use sigma_moe::coordinator::Checkpoint;
use sigma_moe::data::{self, Corpus, WordTokenizer};
use sigma_moe::json::{self, Json};
use sigma_moe::rng::Rng;
use sigma_moe::serving::Sampler;
use sigma_moe::serving::{
    DropReason, EngineBackend, GenRequest, Histogram, MockBackend, Policy,
    Scheduler, StreamEvent,
};
use sigma_moe::tensor::{DType, HostTensor};
use sigma_moe::{flops, Error};

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.coin(0.5)),
        2 => Json::Num((rng.next_f64() * 2e6).round() - 1e6),
        3 => {
            let n = rng.below(12);
            let s: String = (0..n)
                .map(|_| {
                    let c = rng.below(128) as u8;
                    if c.is_ascii_graphic() || c == b' ' {
                        c as char
                    } else {
                        'ü'
                    }
                })
                .collect();
            Json::Str(s)
        }
        4 => Json::Arr((0..rng.below(5))
            .map(|_| random_json(rng, depth - 1))
            .collect()),
        _ => Json::Obj(
            (0..rng.below(5))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn prop_json_roundtrips() {
    let mut rng = Rng::new(1);
    for _ in 0..300 {
        let v = random_json(&mut rng, 3);
        let text = v.to_string_compact();
        let back = Json::parse(&text)
            .unwrap_or_else(|e| panic!("reparse failed on {text}: {e}"));
        assert_eq!(v, back, "roundtrip mismatch for {text}");
    }
}

#[test]
fn prop_json_rejects_truncations() {
    // any strict prefix of a valid non-trivial document must not parse
    let v = json::obj(vec![
        ("a", json::arr(vec![json::num(1.0), json::s("x")])),
        ("b", Json::Bool(true)),
    ]);
    let text = v.to_string_compact();
    for cut in 1..text.len() {
        assert!(
            Json::parse(&text[..cut]).is_err(),
            "prefix unexpectedly parsed: {}",
            &text[..cut]
        );
    }
}

#[test]
fn prop_checkpoint_roundtrips_random_tensors() {
    let mut rng = Rng::new(2);
    let dir = std::env::temp_dir().join("sigma_moe_prop");
    std::fs::create_dir_all(&dir).unwrap();
    for case in 0..20 {
        let n_params = 1 + rng.below(6);
        let params: Vec<(String, HostTensor)> = (0..n_params)
            .map(|i| {
                let dims: Vec<usize> =
                    (0..1 + rng.below(3)).map(|_| 1 + rng.below(7)).collect();
                let n: usize = dims.iter().product();
                let vals: Vec<f32> =
                    (0..n).map(|_| rng.normal() as f32).collect();
                (format!("p{i}"), HostTensor::from_f32(&dims, &vals).unwrap())
            })
            .collect();
        let ck = Checkpoint {
            step: rng.below(100000) as i64,
            preset: format!("case-{case}"),
            params: params.clone(),
            opt: vec![],
        };
        let path = dir.join(format!("{case}.ckpt"));
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.step, ck.step);
        assert_eq!(back.params.len(), params.len());
        for ((n1, t1), (n2, t2)) in params.iter().zip(&back.params) {
            assert_eq!(n1, n2);
            assert_eq!(t1, t2);
        }
    }
}

#[test]
fn prop_batcher_streams_are_contiguous_for_any_shape() {
    let mut rng = Rng::new(3);
    for _ in 0..15 {
        let batch = 1 + rng.below(6);
        let seg = 2 + rng.below(40);
        let mut b =
            data::batcher_for("wikitext", 256, batch, seg, rng.next_u64())
                .unwrap();
        let mut prev: Option<Vec<i32>> = None;
        for _ in 0..4 {
            let w = b.next_window().unwrap();
            assert_eq!(w.shape, vec![batch, seg + 1]);
            let vals = w.as_i32().unwrap();
            assert!(vals.iter().all(|&t| (0..256).contains(&t)));
            if let Some(p) = prev {
                for row in 0..batch {
                    assert_eq!(
                        p[row * (seg + 1) + seg],
                        vals[row * (seg + 1)],
                        "row {row} not contiguous"
                    );
                }
            }
            prev = Some(vals);
        }
    }
}

#[test]
fn prop_word_tokenizer_known_words_roundtrip() {
    let mut rng = Rng::new(4);
    for _ in 0..20 {
        // build a corpus of random words, tokenize a sentence of them
        let n_words = 3 + rng.below(30);
        let words: Vec<String> = (0..n_words)
            .map(|i| format!("w{}x{i}", rng.below(1000)))
            .collect();
        let text = words.join(" ");
        let tok = WordTokenizer::build(&text, n_words + 1).unwrap();
        let enc = tok.encode(&text);
        assert_eq!(enc.len(), words.len());
        assert!(enc.iter().all(|&t| t != 0), "unk leaked for known words");
        assert_eq!(tok.decode(&enc), text);
    }
}

#[test]
fn prop_sampler_greedy_always_argmax() {
    let mut rng = Rng::new(5);
    let s = Sampler::greedy();
    for _ in 0..50 {
        let n = 2 + rng.below(40);
        let logits: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let best = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        let mut r2 = rng.fork(7);
        assert_eq!(s.sample(&logits, &mut r2), Some(best));
    }
}

#[test]
fn prop_moe_fraction_equals_k_over_ne_when_dff_matches() {
    let mut rng = Rng::new(6);
    for _ in 0..40 {
        let g = 8 << rng.below(5);
        let ne = 1 + rng.below(64);
        let k = 1 + rng.below(ne);
        let d_model = 64 + rng.below(512);
        let f = flops::moe_fraction(d_model, ne, g, k, ne * g);
        let want = k as f64 / ne as f64;
        assert!((f - want).abs() < 1e-12, "{f} vs {want}");
    }
}

#[test]
fn prop_corpus_flavors_and_seeds_are_distinct() {
    let mut rng = Rng::new(7);
    for _ in 0..10 {
        let seed = rng.next_u64();
        let mut a = data::by_name("wikitext", 512, seed).unwrap();
        let mut b = data::by_name("c4", 512, seed).unwrap();
        let mut a2 = data::by_name("wikitext", 512, seed ^ 1).unwrap();
        let va = a.take_vec(256);
        assert_ne!(va, b.take_vec(256), "flavors identical");
        assert_ne!(va, a2.take_vec(256), "seeds identical");
    }
}

#[test]
fn prop_tensor_literal_roundtrip() {
    let mut rng = Rng::new(8);
    for _ in 0..20 {
        let dims: Vec<usize> =
            (0..1 + rng.below(3)).map(|_| 1 + rng.below(9)).collect();
        let n: usize = dims.iter().product();
        let vals: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let t = HostTensor::from_f32(&dims, &vals).unwrap();
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }
}

#[test]
fn dtype_errors_are_reported_not_panicked() {
    let t = HostTensor::zeros(DType::I32, &[3]);
    assert!(matches!(t.as_f32(), Err(Error::Shape(_))));
}

fn greq(prompt_len: usize) -> GenRequest {
    GenRequest {
        prompt: vec![1; prompt_len.max(1)],
        max_new_tokens: 2,
        sampler: Sampler::greedy(),
        ..Default::default()
    }
}

#[test]
fn prop_histogram_percentile_monotone_bounded_count_consistent() {
    let mut rng = Rng::new(9);
    for case in 0..25 {
        let n = 1 + rng.below(300);
        let mut h = Histogram::new();
        let mut obs: Vec<f64> = Vec::with_capacity(n);
        for _ in 0..n {
            // magnitudes spanning 1µs .. 100s (the histogram's
            // log-buckets start at 1µs)
            let secs = 10f64.powf(rng.next_f64() * 8.0 - 6.0);
            obs.push(secs);
            h.observe_secs(secs);
        }
        assert_eq!(h.count(), n as u64, "case {case}");
        let max = h.max_secs();
        assert!(h.mean_secs() <= max + 1e-12);
        let mut prev = 0.0;
        for i in 0..=100u32 {
            let p = f64::from(i) / 100.0;
            let v = h.percentile(p);
            // monotone in p
            assert!(
                v >= prev - 1e-12,
                "case {case}: percentile not monotone at p={p}: \
                 {v} < {prev}"
            );
            prev = v;
            // bounded by the observed maximum
            assert!(
                v <= max + 1e-12,
                "case {case}: p={p} exceeds max: {v} > {max}"
            );
            // count-consistent up to log-bucket resolution: each
            // bucket spans 2x, EXCEPT bucket 0, which covers [0, 2µs)
            // with lower edge 0 — interpolated values there can
            // undershoot the smallest observation, so the bounds get
            // one bucket-0 width (2µs) of additive slack.  At least
            // ceil(p*n) observations lie at or below 2v (+slack), and
            // fewer than ceil(p*n) lie below v/2 (-slack).
            let rank = (p * n as f64).ceil().max(1.0) as usize;
            let leq =
                obs.iter().filter(|&&o| o <= 2.0 * v + 2e-6).count();
            assert!(
                leq >= rank.min(n),
                "case {case} p={p}: only {leq}/{n} obs <= 2*{v}"
            );
            let below =
                obs.iter().filter(|&&o| o < v / 2.0 - 2e-6).count();
            assert!(
                below < rank,
                "case {case} p={p}: {below} obs below {v}/2 \
                 (rank {rank})"
            );
        }
    }
}

#[test]
fn prop_chunked_prefill_stream_equivalence_under_mixed_pumps() {
    // randomized submit/pump interleavings over a multi-lane mock with
    // chunked prefill: lanes mid-decode share pumps with lanes mid-
    // prefill (ragged lengths straddling the chunk boundary).  Replay
    // the identical schedule on a single-token backend: every
    // request's token stream and Done result must be identical, the
    // chunked run must never use more pumps, and the chunked-path
    // accounting must cover exactly the prompt tokens.
    const C: usize = 4;
    let mut rng = Rng::new(12);
    for round in 0..15 {
        // one shared op schedule: Some(prompt_len, budget) = submit,
        // None = pump
        let mut ops: Vec<Option<(usize, usize)>> = Vec::new();
        for _ in 0..40 {
            if rng.coin(0.3) {
                let len = match rng.below(5) {
                    0 => C - 1,
                    1 => C,
                    2 => C + 1,
                    3 => 2 * C + 3,
                    _ => 1 + rng.below(3 * C),
                };
                ops.push(Some((len, 1 + rng.below(6))));
            } else {
                ops.push(None);
            }
        }
        let run = |chunk: usize| -> (
            Vec<(Vec<i32>, mpsc::Receiver<StreamEvent>)>,
            u64,
            u64,
        ) {
            let mut b =
                MockBackend::new(3, 50).with_prefill_chunk(chunk);
            let mut streams = Vec::new();
            let mut tag = 0i32;
            for op in &ops {
                match op {
                    Some((len, budget)) => {
                        tag += 1;
                        let prompt: Vec<i32> = (0..*len as i32)
                            .map(|j| (tag * 7 + j) % 50)
                            .collect();
                        let (tx, rx) = mpsc::channel();
                        b.submit_streaming(
                            GenRequest {
                                prompt: prompt.clone(),
                                max_new_tokens: *budget,
                                sampler: Sampler::greedy(),
                                ..Default::default()
                            },
                            tx,
                        );
                        streams.push((prompt, rx));
                    }
                    None => {
                        let _ = b.pump().unwrap();
                    }
                }
            }
            while b.pump().unwrap() > 0 {}
            (streams, b.steps_executed, b.prefill_tokens)
        };
        let (chunked, pumps_c, prefill_tokens) = run(C);
        let (single, pumps_s, _) = run(1);
        assert!(
            pumps_c <= pumps_s,
            "round {round}: chunked used more pumps ({pumps_c} > \
             {pumps_s})"
        );
        let mut total_prompt = 0usize;
        for ((prompt, rx_c), (_, rx_s)) in
            chunked.iter().zip(single.iter())
        {
            total_prompt += prompt.len();
            let collect = |rx: &mpsc::Receiver<StreamEvent>| {
                let mut toks = Vec::new();
                let mut dones = Vec::new();
                while let Ok(ev) = rx.try_recv() {
                    match ev {
                        StreamEvent::Token(t) => toks.push(t),
                        StreamEvent::Done(r) => dones.push(r.tokens),
                        _ => {}
                    }
                }
                (toks, dones)
            };
            let (toks_c, dones_c) = collect(rx_c);
            let (toks_s, dones_s) = collect(rx_s);
            assert_eq!(
                toks_c, toks_s,
                "round {round}: stream diverged for prompt {prompt:?}"
            );
            assert_eq!(dones_c.len(), 1, "round {round}");
            assert_eq!(dones_c, dones_s, "round {round}");
        }
        assert_eq!(
            prefill_tokens as usize, total_prompt,
            "round {round}: chunked accounting must cover exactly the \
             prompt tokens"
        );
    }
}

#[test]
fn prop_speculative_decode_streams_bitwise_match_plain() {
    // randomized submit/pump interleavings over a multi-lane mock with
    // chunked prefill AND speculative decode: for every schedule the
    // token streams and Done results at K ∈ {2, 3} must be bitwise
    // identical to the K = 0 run — speculation may only change how
    // tokens are produced, never which tokens.  The small vocab makes
    // the mock's deterministic stream periodic, so the n-gram drafter
    // warms up and real accepts happen; prompt bigrams colliding with
    // stream bigrams produce wrong drafts, so rollback is exercised
    // too.
    const C: usize = 8;
    const VOCAB: usize = 8;
    let mut rng = Rng::new(21);
    let mut total_drafted = 0u64;
    let mut total_accepted = 0u64;
    for round in 0..12 {
        let mut ops: Vec<Option<(usize, usize)>> = Vec::new();
        for _ in 0..30 {
            if rng.coin(0.3) {
                let len = 1 + rng.below(2 * C);
                // budgets long enough for the drafter to warm up
                ops.push(Some((len, 4 + rng.below(40))));
            } else {
                ops.push(None);
            }
        }
        let run = |speculate: usize| {
            let mut b = MockBackend::new(3, VOCAB)
                .with_prefill_chunk(C)
                .with_speculate(speculate);
            let mut streams = Vec::new();
            let mut tag = 0i32;
            for op in &ops {
                match op {
                    Some((len, budget)) => {
                        tag += 1;
                        let prompt: Vec<i32> = (0..*len as i32)
                            .map(|j| (tag * 5 + j) % VOCAB as i32)
                            .collect();
                        let (tx, rx) = mpsc::channel();
                        b.submit_streaming(
                            GenRequest {
                                prompt,
                                max_new_tokens: *budget,
                                sampler: Sampler::greedy(),
                                ..Default::default()
                            },
                            tx,
                        );
                        streams.push(rx);
                    }
                    None => {
                        let _ = b.pump().unwrap();
                    }
                }
            }
            while b.pump().unwrap() > 0 {}
            let collected: Vec<(Vec<i32>, usize)> = streams
                .iter()
                .map(|rx| {
                    let mut toks = Vec::new();
                    let mut dones = 0usize;
                    while let Ok(ev) = rx.try_recv() {
                        match ev {
                            StreamEvent::Token(t) => toks.push(t),
                            StreamEvent::Done(_) => dones += 1,
                            _ => {}
                        }
                    }
                    (toks, dones)
                })
                .collect();
            (collected, b)
        };
        let (plain, b0) = run(0);
        assert!(
            b0.stats().get("speculate").is_none(),
            "round {round}: K = 0 must export no spec_* families"
        );
        for k in [2usize, 3] {
            let (spec, b) = run(k);
            assert_eq!(
                spec, plain,
                "round {round}: speculative K = {k} diverged from the \
                 plain stream"
            );
            assert!(
                b.spec_accepted <= b.spec_drafted,
                "round {round}: accepted more than was drafted"
            );
            total_drafted += b.spec_drafted;
            total_accepted += b.spec_accepted;
        }
    }
    // the sweep must actually exercise the speculative path, not just
    // fall back to plain decode everywhere
    assert!(total_drafted > 0, "no round ever drafted");
    assert!(total_accepted > 0, "no draft was ever accepted");
}

#[test]
fn prop_spf_take_order_matches_shadow_model() {
    // the scheduler's shortest-prompt-first policy against a brute-
    // force shadow model, under randomized enqueue/take interleavings:
    // every take returns the queued request with minimal prompt length,
    // FIFO among equals
    let mut rng = Rng::new(10);
    for round in 0..20 {
        let s = Scheduler::new(256, Policy::ShortestPrompt);
        let mut held = Vec::new();
        // (id, prompt_len) in arrival order
        let mut shadow: Vec<(u64, usize)> = Vec::new();
        let shortest = |shadow: &[(u64, usize)]| {
            shadow
                .iter()
                .enumerate()
                .min_by_key(|&(i, &(_, l))| (l, i))
                .unwrap()
                .0
        };
        for _op in 0..60 {
            if rng.coin(0.6) || shadow.is_empty() {
                let len = 1 + rng.below(30);
                let (tx, rx) = mpsc::channel();
                let id = s.enqueue(greq(len), None, tx).unwrap();
                held.push(rx);
                shadow.push((id, len));
            } else {
                let taken = s.take_next(Instant::now()).unwrap();
                let (id, len) = shadow.remove(shortest(&shadow));
                assert_eq!(taken.id, id, "round {round}");
                assert_eq!(taken.req.prompt.len(), len);
            }
        }
        while let Some(q) = s.take_next(Instant::now()) {
            let (id, _) = shadow.remove(shortest(&shadow));
            assert_eq!(q.id, id);
        }
        assert!(shadow.is_empty());
    }
}

#[test]
fn prop_deadline_never_yields_expired_each_resolved_once() {
    // randomized enqueue / expire / take interleavings with real time
    // passing: take_next must never yield a request whose deadline had
    // already passed when it was called, and every request must resolve
    // exactly once (admitted-and-taken XOR deadline-dropped)
    let mut rng = Rng::new(11);
    for round in 0..15 {
        let s = Scheduler::new(256, Policy::Deadline);
        let mut rxs: Vec<(u64, mpsc::Receiver<StreamEvent>)> = Vec::new();
        let mut taken: Vec<u64> = Vec::new();
        for _op in 0..40 {
            match rng.below(4) {
                0 | 1 => {
                    let deadline = rng.coin(0.5).then(|| {
                        Duration::from_micros(rng.below(3000) as u64)
                    });
                    let (tx, rx) = mpsc::channel();
                    let id = s
                        .enqueue(greq(1 + rng.below(5)), deadline, tx)
                        .unwrap();
                    rxs.push((id, rx));
                }
                2 => s.expire(Instant::now()),
                _ => {
                    let before = Instant::now();
                    if let Some(q) = s.take_next(before) {
                        assert!(
                            q.deadline.is_none_or(|d| d > before),
                            "round {round}: expired request admitted"
                        );
                        taken.push(q.id);
                    }
                }
            }
            if rng.coin(0.3) {
                std::thread::sleep(Duration::from_micros(500));
            }
        }
        loop {
            let before = Instant::now();
            match s.take_next(before) {
                Some(q) => {
                    assert!(q.deadline.is_none_or(|d| d > before));
                    taken.push(q.id);
                }
                None => break,
            }
        }
        for (id, rx) in &rxs {
            let was_taken = taken.contains(id);
            let (mut dropped, mut admitted) = (0, 0);
            while let Ok(ev) = rx.try_recv() {
                match ev {
                    StreamEvent::Dropped(DropReason::Deadline) => {
                        dropped += 1
                    }
                    StreamEvent::Admitted => admitted += 1,
                    other => panic!("unexpected event {other:?}"),
                }
            }
            if was_taken {
                assert_eq!(
                    (admitted, dropped),
                    (1, 0),
                    "id {id}: taken requests get exactly one Admitted \
                     and no drop"
                );
            } else {
                assert_eq!(
                    (admitted, dropped),
                    (0, 1),
                    "id {id}: untaken requests get exactly one \
                     deadline drop"
                );
            }
        }
        let m = s.metrics_json();
        let g = |k: &str| m.get(k).unwrap().as_f64().unwrap();
        assert_eq!(
            g("enqueued"),
            g("started") + g("dropped_deadline") + g("dropped_dead"),
            "every admission resolves in exactly one counter"
        );
    }
}

#[test]
fn prop_concurrent_expire_and_take_resolve_each_request_once() {
    // one thread expiring, one taking, main thread enqueueing: the
    // expire-vs-take race must still resolve every request in exactly
    // one of {taken, deadline-dropped} and conserve the counters
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;
    for round in 0..5u64 {
        let s = Arc::new(Scheduler::new(512, Policy::Deadline));
        let stop = Arc::new(AtomicBool::new(false));
        let expirer = {
            let (s, stop) = (s.clone(), stop.clone());
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    s.expire(Instant::now());
                    std::thread::yield_now();
                }
            })
        };
        let taker = {
            let (s, stop) = (s.clone(), stop.clone());
            std::thread::spawn(move || {
                let mut ids = Vec::new();
                while !stop.load(Ordering::Relaxed) {
                    if let Some(q) = s.take_next(Instant::now()) {
                        ids.push(q.id);
                    }
                    std::thread::yield_now();
                }
                while let Some(q) = s.take_next(Instant::now()) {
                    ids.push(q.id);
                }
                ids
            })
        };
        let mut rng = Rng::new(100 + round);
        let mut rxs = Vec::new();
        for i in 0..200usize {
            let deadline = rng.coin(0.5).then(|| {
                Duration::from_micros(rng.below(2000) as u64)
            });
            let (tx, rx) = mpsc::channel();
            let id = s.enqueue(greq(1 + (i % 7)), deadline, tx).unwrap();
            rxs.push((id, rx));
            if rng.coin(0.2) {
                std::thread::sleep(Duration::from_micros(200));
            }
        }
        std::thread::sleep(Duration::from_millis(20));
        stop.store(true, Ordering::SeqCst);
        expirer.join().unwrap();
        let taken = taker.join().unwrap();
        for (id, rx) in &rxs {
            let was_taken = taken.contains(id);
            let mut dropped = 0usize;
            while let Ok(ev) = rx.try_recv() {
                if matches!(ev, StreamEvent::Dropped(_)) {
                    dropped += 1;
                }
            }
            assert_eq!(
                usize::from(was_taken) + dropped,
                1,
                "round {round} id {id}: taken={was_taken} \
                 dropped={dropped}"
            );
        }
        let m = s.metrics_json();
        let g = |k: &str| m.get(k).unwrap().as_f64().unwrap();
        assert_eq!(g("depth"), 0.0);
        assert_eq!(
            g("enqueued"),
            g("started") + g("dropped_deadline") + g("dropped_dead")
        );
    }
}
