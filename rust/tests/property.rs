//! Property-based tests (seeded random sweeps — the offline vendor set
//! has no proptest crate, so we drive generation with the library's own
//! PRNG): serialization round-trips, batcher/tokenizer invariants,
//! sampler and analytic-model properties.

use sigma_moe::coordinator::Checkpoint;
use sigma_moe::data::{self, Corpus, WordTokenizer};
use sigma_moe::json::{self, Json};
use sigma_moe::rng::Rng;
use sigma_moe::serving::Sampler;
use sigma_moe::tensor::{DType, HostTensor};
use sigma_moe::{flops, Error};

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.coin(0.5)),
        2 => Json::Num((rng.next_f64() * 2e6).round() - 1e6),
        3 => {
            let n = rng.below(12);
            let s: String = (0..n)
                .map(|_| {
                    let c = rng.below(128) as u8;
                    if c.is_ascii_graphic() || c == b' ' {
                        c as char
                    } else {
                        'ü'
                    }
                })
                .collect();
            Json::Str(s)
        }
        4 => Json::Arr((0..rng.below(5))
            .map(|_| random_json(rng, depth - 1))
            .collect()),
        _ => Json::Obj(
            (0..rng.below(5))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn prop_json_roundtrips() {
    let mut rng = Rng::new(1);
    for _ in 0..300 {
        let v = random_json(&mut rng, 3);
        let text = v.to_string_compact();
        let back = Json::parse(&text)
            .unwrap_or_else(|e| panic!("reparse failed on {text}: {e}"));
        assert_eq!(v, back, "roundtrip mismatch for {text}");
    }
}

#[test]
fn prop_json_rejects_truncations() {
    // any strict prefix of a valid non-trivial document must not parse
    let v = json::obj(vec![
        ("a", json::arr(vec![json::num(1.0), json::s("x")])),
        ("b", Json::Bool(true)),
    ]);
    let text = v.to_string_compact();
    for cut in 1..text.len() {
        assert!(
            Json::parse(&text[..cut]).is_err(),
            "prefix unexpectedly parsed: {}",
            &text[..cut]
        );
    }
}

#[test]
fn prop_checkpoint_roundtrips_random_tensors() {
    let mut rng = Rng::new(2);
    let dir = std::env::temp_dir().join("sigma_moe_prop");
    std::fs::create_dir_all(&dir).unwrap();
    for case in 0..20 {
        let n_params = 1 + rng.below(6);
        let params: Vec<(String, HostTensor)> = (0..n_params)
            .map(|i| {
                let dims: Vec<usize> =
                    (0..1 + rng.below(3)).map(|_| 1 + rng.below(7)).collect();
                let n: usize = dims.iter().product();
                let vals: Vec<f32> =
                    (0..n).map(|_| rng.normal() as f32).collect();
                (format!("p{i}"), HostTensor::from_f32(&dims, &vals).unwrap())
            })
            .collect();
        let ck = Checkpoint {
            step: rng.below(100000) as i64,
            preset: format!("case-{case}"),
            params: params.clone(),
            opt: vec![],
        };
        let path = dir.join(format!("{case}.ckpt"));
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.step, ck.step);
        assert_eq!(back.params.len(), params.len());
        for ((n1, t1), (n2, t2)) in params.iter().zip(&back.params) {
            assert_eq!(n1, n2);
            assert_eq!(t1, t2);
        }
    }
}

#[test]
fn prop_batcher_streams_are_contiguous_for_any_shape() {
    let mut rng = Rng::new(3);
    for _ in 0..15 {
        let batch = 1 + rng.below(6);
        let seg = 2 + rng.below(40);
        let mut b =
            data::batcher_for("wikitext", 256, batch, seg, rng.next_u64())
                .unwrap();
        let mut prev: Option<Vec<i32>> = None;
        for _ in 0..4 {
            let w = b.next_window().unwrap();
            assert_eq!(w.shape, vec![batch, seg + 1]);
            let vals = w.as_i32().unwrap();
            assert!(vals.iter().all(|&t| (0..256).contains(&t)));
            if let Some(p) = prev {
                for row in 0..batch {
                    assert_eq!(
                        p[row * (seg + 1) + seg],
                        vals[row * (seg + 1)],
                        "row {row} not contiguous"
                    );
                }
            }
            prev = Some(vals);
        }
    }
}

#[test]
fn prop_word_tokenizer_known_words_roundtrip() {
    let mut rng = Rng::new(4);
    for _ in 0..20 {
        // build a corpus of random words, tokenize a sentence of them
        let n_words = 3 + rng.below(30);
        let words: Vec<String> = (0..n_words)
            .map(|i| format!("w{}x{i}", rng.below(1000)))
            .collect();
        let text = words.join(" ");
        let tok = WordTokenizer::build(&text, n_words + 1).unwrap();
        let enc = tok.encode(&text);
        assert_eq!(enc.len(), words.len());
        assert!(enc.iter().all(|&t| t != 0), "unk leaked for known words");
        assert_eq!(tok.decode(&enc), text);
    }
}

#[test]
fn prop_sampler_greedy_always_argmax() {
    let mut rng = Rng::new(5);
    let s = Sampler::greedy();
    for _ in 0..50 {
        let n = 2 + rng.below(40);
        let logits: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let best = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        let mut r2 = rng.fork(7);
        assert_eq!(s.sample(&logits, &mut r2), best);
    }
}

#[test]
fn prop_moe_fraction_equals_k_over_ne_when_dff_matches() {
    let mut rng = Rng::new(6);
    for _ in 0..40 {
        let g = 8 << rng.below(5);
        let ne = 1 + rng.below(64);
        let k = 1 + rng.below(ne);
        let d_model = 64 + rng.below(512);
        let f = flops::moe_fraction(d_model, ne, g, k, ne * g);
        let want = k as f64 / ne as f64;
        assert!((f - want).abs() < 1e-12, "{f} vs {want}");
    }
}

#[test]
fn prop_corpus_flavors_and_seeds_are_distinct() {
    let mut rng = Rng::new(7);
    for _ in 0..10 {
        let seed = rng.next_u64();
        let mut a = data::by_name("wikitext", 512, seed).unwrap();
        let mut b = data::by_name("c4", 512, seed).unwrap();
        let mut a2 = data::by_name("wikitext", 512, seed ^ 1).unwrap();
        let va = a.take_vec(256);
        assert_ne!(va, b.take_vec(256), "flavors identical");
        assert_ne!(va, a2.take_vec(256), "seeds identical");
    }
}

#[test]
fn prop_tensor_literal_roundtrip() {
    let mut rng = Rng::new(8);
    for _ in 0..20 {
        let dims: Vec<usize> =
            (0..1 + rng.below(3)).map(|_| 1 + rng.below(9)).collect();
        let n: usize = dims.iter().product();
        let vals: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let t = HostTensor::from_f32(&dims, &vals).unwrap();
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }
}

#[test]
fn dtype_errors_are_reported_not_panicked() {
    let t = HostTensor::zeros(DType::I32, &[3]);
    assert!(matches!(t.as_f32(), Err(Error::Shape(_))));
}
