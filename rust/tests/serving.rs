//! Integration tests for the serving frontend: scheduler policies,
//! backpressure, HTTP framing, streaming, and the loadgen dry-run —
//! all over the device-free `MockBackend`, so they run with no
//! artifacts built (unlike `coordinator.rs`).

use std::io::{BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use sigma_moe::json::{self, Json};
use sigma_moe::serving::loadgen::{self, LoadgenCfg};
use sigma_moe::serving::server::ServerConfig;
use sigma_moe::serving::telemetry;
use sigma_moe::serving::{MockBackend, Policy};

/// Raw-socket POST helper returning (status, headers, body-bytes) with
/// chunked bodies reassembled.
fn post(
    addr: &SocketAddr,
    path: &str,
    body: &str,
) -> (u16, Vec<(String, String)>, Vec<u8>) {
    let stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let mut w = stream.try_clone().unwrap();
    w.write_all(
        format!(
            "POST {path} HTTP/1.1\r\nHost: t\r\n\
             Content-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        )
        .as_bytes(),
    )
    .unwrap();
    read_response(stream)
}

fn get(addr: &SocketAddr, path: &str) -> (u16, Vec<(String, String)>, Vec<u8>) {
    let stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let mut w = stream.try_clone().unwrap();
    w.write_all(
        format!("GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n")
            .as_bytes(),
    )
    .unwrap();
    read_response(stream)
}

fn read_response(stream: TcpStream) -> (u16, Vec<(String, String)>, Vec<u8>) {
    let mut r = BufReader::new(stream);
    let (status, headers) = loadgen::read_head(&mut r).expect("response head");
    let chunked = headers
        .iter()
        .any(|(k, v)| k == "transfer-encoding" && v == "chunked");
    let body = if chunked {
        loadgen::read_chunked(&mut r, |_| {}).expect("chunked body")
    } else {
        let len: usize = headers
            .iter()
            .find(|(k, _)| k == "content-length")
            .map(|(_, v)| v.parse().unwrap())
            .unwrap_or(0);
        let mut buf = vec![0u8; len];
        r.read_exact(&mut buf).unwrap();
        buf
    };
    (status, headers, body)
}

fn json_of(body: &[u8]) -> Json {
    Json::parse(std::str::from_utf8(body).expect("utf8 body")).expect("json")
}

#[test]
fn healthz_metrics_and_routing() {
    loadgen::with_mock_server(
        2,
        64,
        Duration::ZERO,
        ServerConfig::default(),
        |addr| {
            let (status, _, body) = get(&addr, "/healthz");
            assert_eq!(status, 200);
            assert_eq!(
                json_of(&body).get("status").unwrap().as_str().unwrap(),
                "ok"
            );

            let (status, _, body) = get(&addr, "/metrics");
            assert_eq!(status, 200);
            let doc = json_of(&body);
            assert!(doc.get("scheduler").is_ok());
            assert!(doc.get("engine").is_ok());
            assert!(doc
                .get("server")
                .unwrap()
                .get("driver_alive")
                .unwrap()
                .as_bool()
                .unwrap());

            let (status, _, _) = get(&addr, "/nope");
            assert_eq!(status, 404);
            let (status, _, _) = get(&addr, "/v1/completions");
            assert_eq!(status, 405);
            Ok(())
        },
    )
    .unwrap();
}

#[test]
fn unary_completion_returns_deterministic_tokens() {
    loadgen::with_mock_server(
        2,
        64,
        Duration::ZERO,
        ServerConfig::default(),
        |addr| {
            let (status, _, body) = post(
                &addr,
                "/v1/completions",
                r#"{"prompt": [3, 4], "max_tokens": 5}"#,
            );
            assert_eq!(status, 200);
            let doc = json_of(&body);
            let tokens: Vec<i32> = doc
                .get("tokens")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|t| t.as_i64().unwrap() as i32)
                .collect();
            let expect: Vec<i32> = (0..5)
                .map(|i| MockBackend::expected_token(&[3, 4], i, 64))
                .collect();
            assert_eq!(tokens, expect);
            assert_eq!(
                doc.get("prompt_len").unwrap().as_usize().unwrap(),
                2
            );
            assert!(doc.get("run_ms").unwrap().as_f64().unwrap() >= 0.0);
            Ok(())
        },
    )
    .unwrap();
}

#[test]
fn streaming_completion_frames_tokens_as_ndjson_chunks() {
    loadgen::with_mock_server(
        1,
        64,
        Duration::ZERO,
        ServerConfig::default(),
        |addr| {
            let (status, headers, body) = post(
                &addr,
                "/v1/completions",
                r#"{"prompt": [9], "max_tokens": 4, "stream": true}"#,
            );
            assert_eq!(status, 200);
            assert!(headers
                .iter()
                .any(|(k, v)| k == "transfer-encoding" && v == "chunked"));
            let text = String::from_utf8(body).unwrap();
            let lines: Vec<Json> = text
                .lines()
                .filter(|l| !l.is_empty())
                .map(|l| Json::parse(l).expect("ndjson line"))
                .collect();
            // admitted marker, 4 token lines, done line
            assert_eq!(
                lines[0].get("event").unwrap().as_str().unwrap(),
                "admitted"
            );
            let toks: Vec<i32> = lines
                .iter()
                .filter_map(|l| l.opt("token"))
                .map(|t| t.as_i64().unwrap() as i32)
                .collect();
            let expect: Vec<i32> = (0..4)
                .map(|i| MockBackend::expected_token(&[9], i, 64))
                .collect();
            assert_eq!(toks, expect);
            let done = lines.last().unwrap();
            assert!(done.get("done").unwrap().as_bool().unwrap());
            assert_eq!(done.get("tokens").unwrap().as_usize().unwrap(), 4);
            Ok(())
        },
    )
    .unwrap();
}

#[test]
fn queue_overflow_answers_429_with_retry_after() {
    // 1 slow lane + queue capacity 1: r1 occupies the lane, r2 fills
    // the queue, r3 must bounce with 429.
    let cfg = ServerConfig {
        queue_cap: 1,
        ..Default::default()
    };
    loadgen::with_mock_server(
        1,
        64,
        Duration::from_millis(20),
        cfg,
        |addr| {
            let slow = r#"{"prompt": [1], "max_tokens": 100}"#;
            let hold1 = spawn_post(addr, slow);
            // let r1 reach the lane so r2 sits alone in the queue
            std::thread::sleep(Duration::from_millis(200));
            let hold2 = spawn_post(addr, slow);
            std::thread::sleep(Duration::from_millis(100));
            let (status, headers, _) =
                post(&addr, "/v1/completions", slow);
            assert_eq!(status, 429);
            assert!(headers
                .iter()
                .any(|(k, v)| k == "retry-after" && v == "1"));
            let (s1, _, _) = hold1.join().unwrap();
            let (s2, _, _) = hold2.join().unwrap();
            assert_eq!((s1, s2), (200, 200));
            Ok(())
        },
    )
    .unwrap();
}

fn spawn_post(
    addr: SocketAddr,
    body: &'static str,
) -> std::thread::JoinHandle<(u16, Vec<(String, String)>, Vec<u8>)> {
    std::thread::spawn(move || post(&addr, "/v1/completions", body))
}

#[test]
fn deadline_policy_drops_expired_requests_with_503() {
    let cfg = ServerConfig {
        policy: Policy::Deadline,
        ..Default::default()
    };
    loadgen::with_mock_server(
        1,
        64,
        Duration::from_millis(10),
        cfg,
        |addr| {
            // occupy the single lane for a while
            let hold = spawn_post(
                addr,
                r#"{"prompt": [1], "max_tokens": 100}"#,
            );
            std::thread::sleep(Duration::from_millis(200));
            // this deadline expires long before the lane frees up
            let (status, _, body) = post(
                &addr,
                "/v1/completions",
                r#"{"prompt": [2], "max_tokens": 4, "deadline_ms": 50}"#,
            );
            assert_eq!(status, 503);
            assert_eq!(
                json_of(&body).get("error").unwrap().as_str().unwrap(),
                "deadline"
            );
            let (s, _, _) = hold.join().unwrap();
            assert_eq!(s, 200);
            Ok(())
        },
    )
    .unwrap();
}

#[test]
fn bad_requests_answer_400() {
    loadgen::with_mock_server(
        1,
        64,
        Duration::ZERO,
        ServerConfig { vocab: Some(64), ..Default::default() },
        |addr| {
            for body in [
                "not json",
                r#"{"prompt": []}"#,
                r#"{"prompt": [9999]}"#,
                r#"{"prompt": [1], "temperature": -1}"#,
            ] {
                let (status, _, resp) = post(&addr, "/v1/completions", body);
                assert_eq!(status, 400, "{body}");
                assert!(json_of(&resp).get("error").is_ok());
            }
            Ok(())
        },
    )
    .unwrap();
}

/// One raw keep-alive request: write it, return nothing (responses are
/// read by the caller so multiple requests can share one socket).
fn write_request(w: &mut impl Write, body: &str, close: bool) {
    w.write_all(
        format!(
            "POST /v1/completions HTTP/1.1\r\nHost: t\r\n\
             Content-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: {}\r\n\r\n{body}",
            body.len(),
            if close { "close" } else { "keep-alive" },
        )
        .as_bytes(),
    )
    .unwrap();
}

fn header_of<'h>(
    headers: &'h [(String, String)],
    name: &str,
) -> Option<&'h str> {
    headers
        .iter()
        .find(|(k, _)| k == name)
        .map(|(_, v)| v.as_str())
}

#[test]
fn keepalive_serves_sequential_requests_on_one_connection() {
    loadgen::with_mock_server(
        2,
        64,
        Duration::ZERO,
        ServerConfig::default(),
        |addr| {
            let stream = TcpStream::connect(addr).expect("connect");
            stream
                .set_read_timeout(Some(Duration::from_secs(30)))
                .unwrap();
            let mut w = stream.try_clone().unwrap();
            let mut r = BufReader::new(stream.try_clone().unwrap());
            // three unary requests back to back on ONE socket
            for i in 0..3 {
                let body = format!(
                    r#"{{"prompt": [{}], "max_tokens": 2}}"#,
                    i + 1
                );
                write_request(&mut w, &body, false);
                let (status, headers) =
                    loadgen::read_head(&mut r).expect("head");
                assert_eq!(status, 200, "request {i}");
                assert_eq!(
                    header_of(&headers, "connection"),
                    Some("keep-alive")
                );
                let len: usize = header_of(&headers, "content-length")
                    .unwrap()
                    .parse()
                    .unwrap();
                let mut buf = vec![0u8; len];
                r.read_exact(&mut buf).unwrap();
                let doc = json_of(&buf);
                assert_eq!(
                    doc.get("tokens").unwrap().as_arr().unwrap().len(),
                    2
                );
            }
            // a chunked streaming response also keeps the socket alive
            write_request(
                &mut w,
                r#"{"prompt": [7], "max_tokens": 3, "stream": true}"#,
                false,
            );
            let (status, headers) =
                loadgen::read_head(&mut r).expect("stream head");
            assert_eq!(status, 200);
            assert_eq!(
                header_of(&headers, "transfer-encoding"),
                Some("chunked")
            );
            let body =
                loadgen::read_chunked(&mut r, |_| {}).expect("chunks");
            let tokens = String::from_utf8(body)
                .unwrap()
                .lines()
                .filter(|l| l.contains("\"token\""))
                .count();
            assert_eq!(tokens, 3);
            // Connection: close is honored and ends the session
            write_request(
                &mut w,
                r#"{"prompt": [9], "max_tokens": 1}"#,
                true,
            );
            let (status, headers) =
                loadgen::read_head(&mut r).expect("final head");
            assert_eq!(status, 200);
            assert_eq!(header_of(&headers, "connection"), Some("close"));
            let len: usize = header_of(&headers, "content-length")
                .unwrap()
                .parse()
                .unwrap();
            let mut buf = vec![0u8; len];
            r.read_exact(&mut buf).unwrap();
            let mut probe = [0u8; 1];
            let n = r.read(&mut probe).unwrap_or(0);
            assert_eq!(n, 0, "server must close after Connection: close");
            Ok(())
        },
    )
    .unwrap();
}

#[test]
fn loadgen_pool_reuses_connections() {
    loadgen::with_mock_server(
        4,
        64,
        Duration::ZERO,
        ServerConfig::default(),
        |addr| {
            let pool = loadgen::ConnPool::new(addr);
            let body = json::obj(vec![
                ("prompt", json::arr(vec![json::num(3.0)])),
                ("max_tokens", json::num(1.0)),
            ]);
            for _ in 0..4 {
                let o = pool
                    .send(&body, Duration::from_secs(30))
                    .expect("pooled send");
                assert_eq!(o.status, 200);
                assert_eq!(o.tokens, 1);
            }
            // sequential sends ride a single pooled connection
            assert_eq!(pool.idle_count(), 1);
            Ok(())
        },
    )
    .unwrap();
}

#[test]
fn request_id_resolves_via_trace_endpoint_and_prom_scrape() {
    loadgen::with_mock_server(
        2,
        64,
        Duration::ZERO,
        ServerConfig::default(),
        |addr| {
            let (status, headers, body) = post(
                &addr,
                "/v1/completions",
                r#"{"prompt": [5, 6], "max_tokens": 3}"#,
            );
            assert_eq!(status, 200);
            let rid = header_of(&headers, "x-request-id")
                .expect("unary X-Request-Id")
                .to_string();
            assert_eq!(
                json_of(&body).get("id").unwrap().as_usize().unwrap(),
                rid.parse::<usize>().unwrap()
            );

            // streamed responses carry the header on the chunked head
            let (status, headers, _) = post(
                &addr,
                "/v1/completions",
                r#"{"prompt": [8], "max_tokens": 2, "stream": true}"#,
            );
            assert_eq!(status, 200);
            assert!(header_of(&headers, "x-request-id").is_some());

            // the id from the response header resolves to a span tree
            let (status, _, body) =
                get(&addr, &format!("/v1/trace/{rid}"));
            assert_eq!(status, 200);
            let span = json_of(&body);
            assert!(span.get("complete").unwrap().as_bool().unwrap());
            assert_eq!(
                span.get("outcome").unwrap().as_str().unwrap(),
                "done"
            );
            assert_eq!(span.get("tokens").unwrap().as_usize().unwrap(), 3);
            let stages: Vec<String> = span
                .get("stages")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|s| {
                    s.get("stage").unwrap().as_str().unwrap().to_string()
                })
                .collect();
            for want in
                ["queued", "placed", "prefill", "first_token", "terminal"]
            {
                assert!(
                    stages.iter().any(|s| s == want),
                    "missing stage {want} in {stages:?}"
                );
            }
            let (status, _, _) = get(&addr, "/v1/trace/999999");
            assert_eq!(status, 404);

            // ?format=prom parses as Prometheus text exposition with
            // the stage and expert families present; raw expert counts
            // land on the driver's publish cadence, so poll for them
            let mut fleet_tokens = 0.0;
            for _ in 0..100 {
                let (status, headers, body) =
                    get(&addr, "/metrics?format=prom");
                assert_eq!(status, 200);
                assert!(header_of(&headers, "content-type")
                    .unwrap()
                    .starts_with("text/plain"));
                let text = String::from_utf8(body).unwrap();
                telemetry::validate_prom(
                    &text,
                    &["sigma_moe_stage_", "sigma_moe_experts_"],
                )
                .expect("prom exposition");
                let doc = json_of(&get(&addr, "/metrics").2);
                fleet_tokens = doc
                    .get("experts")
                    .unwrap()
                    .get("fleet")
                    .unwrap()
                    .get("layers")
                    .unwrap()
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|l| l.get("tokens_k").unwrap().as_f64().unwrap())
                    .sum();
                if fleet_tokens > 0.0 {
                    break;
                }
                std::thread::sleep(Duration::from_millis(50));
            }
            assert!(fleet_tokens > 0.0, "expert counts never published");
            Ok(())
        },
    )
    .unwrap();
}

#[test]
fn loadgen_dry_run_writes_a_parsable_report() {
    let out = std::env::temp_dir().join(format!(
        "bench_serve_test_{}.json",
        std::process::id()
    ));
    let cfg = LoadgenCfg {
        requests: 12,
        rps: 200.0,
        prompt_len: (2, 6),
        max_new: (2, 6),
        vocab: 64,
        stream_fraction: 0.5,
        seed: 3,
        timeout: Duration::from_secs(30),
        ..Default::default()
    };
    let row = loadgen::dry_run(&cfg, 4, 1).expect("dry run");
    sigma_moe::bench_util::write_bench_json(
        &out,
        "sigma-moe/serve/v1",
        vec![row],
    )
    .expect("write report");
    let text = std::fs::read_to_string(&out).unwrap();
    let doc = Json::parse(&text).expect("report json");
    assert_eq!(
        doc.get("schema").unwrap().as_str().unwrap(),
        "sigma-moe/serve/v1"
    );
    let rows = doc.get("results").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 1);
    let row = &rows[0];
    assert_eq!(row.get("mode").unwrap().as_str().unwrap(), "mock-dry-run");
    assert_eq!(row.get("requests").unwrap().as_usize().unwrap(), 12);
    assert_eq!(row.get("ok").unwrap().as_usize().unwrap(), 12);
    assert_eq!(row.get("errors").unwrap().as_usize().unwrap(), 0);
    assert!(row.get("tokens_total").unwrap().as_f64().unwrap() > 0.0);
    assert!(
        row.get("latency").unwrap().get("p50_ms").unwrap().as_f64().unwrap()
            > 0.0
    );
    // the embedded server metrics made it into the report
    let sched = row.get("server_metrics").unwrap().get("scheduler").unwrap();
    assert_eq!(sched.get("completed").unwrap().as_usize().unwrap(), 12);
    let _ = std::fs::remove_file(&out);
}

#[test]
fn loadgen_dry_run_with_prefix_cache_reports_hits_end_to_end() {
    // shared-prefix workload over an armed mock fleet: the report row
    // carries the cache columns, the embedded metrics document carries
    // the shared-cache section, and the validated prom scrape (inside
    // dry_run_with_prom) proves both new families render populated
    let cfg = LoadgenCfg {
        requests: 12,
        rps: 50.0,
        prompt_len: (16, 32),
        prompt_dist: loadgen::PromptDist::SharedPrefix,
        shared_prefix_overlap: 0.5,
        max_new: (2, 4),
        vocab: 64,
        stream_fraction: 1.0, // every request reports a TTFT
        prefill_chunk: 8,
        prefix_cache: Some(1 << 20),
        seed: 7,
        timeout: Duration::from_secs(30),
        ..Default::default()
    };
    let (row, prom) =
        loadgen::dry_run_with_prom(&cfg, 4, 1).expect("armed dry run");
    assert_eq!(row.get("ok").unwrap().as_usize().unwrap(), 12);
    assert_eq!(
        row.get("prefix_cache_budget_bytes").unwrap().as_f64().unwrap(),
        (1u64 << 20) as f64
    );
    // the 16-token shared prefix spans two chunk-8 boundaries, so the
    // arrival-ordered client prediction sees repeats
    assert!(
        row.get("prefix_cache_predicted_hit_rate")
            .unwrap()
            .as_f64()
            .unwrap()
            > 0.0
    );
    for col in ["ttft_cache_hit", "ttft_cache_miss"] {
        row.get(col).unwrap_or_else(|_| panic!("missing column {col}"));
    }
    // authoritative server-side counters: every admitted prompt probed,
    // and at 50 rps the first prompt's snapshot lands long before the
    // next arrival, so at least one later prompt restored from it
    let cache =
        row.get("server_metrics").unwrap().get("prefix_cache").unwrap();
    let hits = cache.get("hits").unwrap().as_f64().unwrap();
    let misses = cache.get("misses").unwrap().as_f64().unwrap();
    assert!(hits >= 1.0, "no cache hits: {cache:?}");
    assert_eq!(hits + misses, 12.0);
    assert!(
        row.get("prefix_cache_hit_rate").unwrap().as_f64().unwrap() > 0.0
    );
    assert!(cache.get("bytes").unwrap().as_f64().unwrap() > 0.0);
    // both exposition families made it through the renderer
    assert!(prom.contains("sigma_moe_prefix_cache_hits"));
    assert!(prom.contains("sigma_moe_engine_prefix_cache_hits"));
}
