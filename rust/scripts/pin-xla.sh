#!/usr/bin/env bash
# Pin the `xla` git dependency to the current upstream rev and generate
# Cargo.lock, making the build reproducible (ROADMAP open item).  Needs
# network access — the offline build containers cannot resolve a rev,
# which is why the pin is scripted instead of hard-coded.
#
#   cd rust && scripts/pin-xla.sh
#   git add Cargo.toml Cargo.lock && git commit -m "Pin xla rev"
set -euo pipefail

cd "$(dirname "$0")/.."
REPO_URL="https://github.com/LaurentMazare/xla-rs"

REV=$(git ls-remote "$REPO_URL" HEAD | cut -f1)
if [ -z "$REV" ]; then
    echo "error: could not resolve $REPO_URL HEAD (no network?)" >&2
    exit 1
fi
echo "resolved $REPO_URL @ $REV"

if grep -q 'branch = "main"' Cargo.toml; then
    sed -i.bak \
        "s|xla-rs\", branch = \"main\"|xla-rs\", rev = \"$REV\"|" \
        Cargo.toml
    rm -f Cargo.toml.bak
    echo "Cargo.toml: pinned xla to rev $REV"
else
    echo "Cargo.toml: already pinned (no branch = \"main\" line); leaving as is"
fi

cargo generate-lockfile
echo "Cargo.lock generated — commit Cargo.toml and Cargo.lock"
