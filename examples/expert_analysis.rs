//! Expert-utilization analysis (paper Figs. 3, 6, 7): train the σ-MoE
//! and the collapse-prone "softmax (renorm.)" ablation for the same
//! number of steps, then compare the selection-weight distributions and
//! the co-occurrence structure.
//!
//!     make artifacts && cargo run --release --example expert_analysis
//!
//! Environment: STEPS (default 150)

use sigma_moe::analysis::ExpertStats;
use sigma_moe::coordinator::Trainer;
use sigma_moe::data;
use sigma_moe::runtime::{Client, ModelBundle};
use sigma_moe::Result;

fn main() -> Result<()> {
    let steps: usize = std::env::var("STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(150);
    let client = Client::cpu()?;

    for (label, preset) in [
        ("sigma-moe (sigmoid)", "tiny-moe"),
        ("softmax (renorm.)", "tiny-moe-softmax_renorm"),
    ] {
        let dir = sigma_moe::artifacts_root().join(preset);
        let bundle = match ModelBundle::load(&client, &dir) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("skipping {label}: {e}");
                continue;
            }
        };
        let m = &bundle.manifest;
        eprintln!("\n=== {label}: training {steps} steps ===");
        let mut trainer = Trainer::new(&bundle, 42)?;
        let mut batcher = data::batcher_for(
            "wikitext", m.model.vocab_size, m.batch_size,
            m.model.context, 42)?;
        trainer.train(&mut batcher, steps, |so| {
            if (so.step + 1) % 50 == 0 {
                eprintln!("  step {} loss {:.3}", so.step + 1, so.loss);
            }
        })?;

        // accumulate eval-time selection statistics (Fig. 3 uses the
        // validation set)
        let mut eval_batcher = data::batcher_for(
            "wikitext", m.model.vocab_size, m.batch_size,
            m.model.context, 99)?;
        let mut stats =
            ExpertStats::new(m.model.n_layers, m.model.n_experts);
        for _ in 0..12 {
            let ev = trainer.evaluate(&mut eval_batcher, 1)?;
            stats.accumulate(&ev.stats)?;
        }
        let rep = stats.report();
        println!("\n-- {label} --");
        let mid = m.model.n_layers / 2;
        print!("{}", rep.format_layer(mid));
        let collapsed = rep.collapsed_layers();
        println!(
            "collapsed layers: {}",
            if collapsed.is_empty() {
                "none".to_string()
            } else {
                format!("{collapsed:?}")
            }
        );
        if let Some(cooc) = &stats.cooccurrence {
            let e = m.model.n_experts;
            println!("co-occurrence (layer {mid}, row-normalized %):");
            for i in 0..e.min(8) {
                let row: Vec<f64> =
                    (0..e).map(|j| cooc[mid][i * e + j]).collect();
                let sum: f64 = row.iter().sum::<f64>().max(1e-9);
                let cells: Vec<String> = row
                    .iter()
                    .take(8)
                    .map(|v| format!("{:3.0}", 100.0 * v / sum))
                    .collect();
                println!("  e{i:<2} {}", cells.join(" "));
            }
        }
    }
    println!(
        "\npaper expectation: sigmoid utilization stays broad; softmax \
         (renorm.) concentrates onto few experts (Fig. 3/7)."
    );
    Ok(())
}
