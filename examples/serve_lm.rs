//! Serving example: briefly train the tiny σ-MoE, then serve a wave of
//! generation requests through the continuous-batching engine and report
//! per-request latency and aggregate throughput (a serving-paper-style
//! readout over the AOT `step_fwd` executable).
//!
//!     make artifacts && cargo run --release --example serve_lm

use sigma_moe::coordinator::Trainer;
use sigma_moe::data;
use sigma_moe::runtime::{Client, ModelBundle};
use sigma_moe::serving::{Engine, GenRequest, Sampler};
use sigma_moe::Result;

fn main() -> Result<()> {
    let client = Client::cpu()?;
    let dir = sigma_moe::artifacts_root().join("tiny-moe");
    let bundle = ModelBundle::load(&client, &dir)?;
    let m = &bundle.manifest;

    // short warm-up training so generations aren't pure noise
    eprintln!("warm-up training (80 steps) ...");
    let mut trainer = Trainer::new(&bundle, 3)?;
    let mut batcher = data::batcher_for(
        "wikitext", m.model.vocab_size, m.batch_size, m.model.context, 3)?;
    trainer.train(&mut batcher, 80, |so| {
        if (so.step + 1) % 20 == 0 {
            eprintln!("  step {} loss {:.3}", so.step + 1, so.loss);
        }
    })?;

    let mut engine = Engine::new(&bundle, &trainer.params()?, 17)?;
    eprintln!(
        "engine ready: {} lanes (serve_batch from the manifest)",
        engine.n_lanes()
    );

    // a wave of requests, 3x oversubscribed vs lanes, mixed lengths
    let mut corpus = data::by_name("wikitext", m.model.vocab_size, 23)?;
    let n_req = engine.n_lanes() * 3;
    let mut rxs = Vec::new();
    let t0 = std::time::Instant::now();
    for i in 0..n_req {
        rxs.push(engine.submit(GenRequest {
            prompt: corpus.take_vec(4 + (i % 5) * 3),
            max_new_tokens: 12 + (i % 3) * 8,
            sampler: Sampler { temperature: 0.9, top_k: 40, greedy: false },
        }));
    }
    let results = engine.run_to_completion(rxs)?;
    let wall = t0.elapsed().as_secs_f64();

    let total_new: usize = results.iter().map(|r| r.tokens.len()).sum();
    let mut queue: Vec<f64> =
        results.iter().map(|r| r.queue_time.as_secs_f64() * 1e3).collect();
    queue.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p = |v: &[f64], q: f64| v[((v.len() - 1) as f64 * q) as usize];

    println!("\n== serving summary ==");
    println!("requests          : {}", results.len());
    println!("lanes             : {}", engine.n_lanes());
    println!("generated tokens  : {total_new}");
    println!("wall time         : {wall:.2}s");
    println!("throughput        : {:.1} tok/s", total_new as f64 / wall);
    println!(
        "queue latency ms  : p50 {:.1}  p90 {:.1}  max {:.1}",
        p(&queue, 0.5),
        p(&queue, 0.9),
        queue.last().unwrap()
    );
    println!(
        "batch occupancy   : {:.2} of {} lanes ({:.2} gen-only)",
        engine.stats()["mean_batch_occupancy"],
        engine.n_lanes(),
        engine.stats()["mean_gen_occupancy"]
    );
    println!(
        "device traffic    : {}",
        engine.transfer_stats().report_per_step(engine.steps_executed)
    );
    // show one generation
    let r0 = &results[0];
    println!(
        "\nsample generation: prompt {:?} -> {:?}",
        &r0.prompt, &r0.tokens
    );
    Ok(())
}
