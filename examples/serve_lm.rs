//! Serving example: briefly train the tiny σ-MoE, serve a wave of
//! generation requests through the continuous-batching engine in
//! process, then stand the HTTP frontend up on an ephemeral port and
//! drive it with streaming and non-streaming `/v1/completions` calls
//! (a serving-paper-style readout over the AOT `step_fwd` executable).
//!
//!     make artifacts && cargo run --release --example serve_lm

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use sigma_moe::coordinator::Trainer;
use sigma_moe::data;
use sigma_moe::json::{self, Json};
use sigma_moe::runtime::{Client, Manifest, ModelBundle};
use sigma_moe::serving::{
    loadgen, server, Engine, GenRequest, Sampler, ServerConfig,
};
use sigma_moe::Result;

fn main() -> Result<()> {
    let client = Client::cpu()?;
    let dir = sigma_moe::artifacts_root().join("tiny-moe");
    let bundle = ModelBundle::load(&client, &dir)?;
    let m = &bundle.manifest;

    // short warm-up training so generations aren't pure noise
    eprintln!("warm-up training (80 steps) ...");
    let mut trainer = Trainer::new(&bundle, 3)?;
    let mut batcher = data::batcher_for(
        "wikitext", m.model.vocab_size, m.batch_size, m.model.context, 3)?;
    trainer.train(&mut batcher, 80, |so| {
        if (so.step + 1) % 20 == 0 {
            eprintln!("  step {} loss {:.3}", so.step + 1, so.loss);
        }
    })?;

    let mut engine = Engine::new(&bundle, &trainer.params()?, 17)?;
    eprintln!(
        "engine ready: {} lanes (serve_batch from the manifest)",
        engine.n_lanes()
    );

    // a wave of requests, 3x oversubscribed vs lanes, mixed lengths
    let mut corpus = data::by_name("wikitext", m.model.vocab_size, 23)?;
    let n_req = engine.n_lanes() * 3;
    let mut rxs = Vec::new();
    let t0 = std::time::Instant::now();
    for i in 0..n_req {
        rxs.push(engine.submit(GenRequest {
            prompt: corpus.take_vec(4 + (i % 5) * 3),
            max_new_tokens: 12 + (i % 3) * 8,
            sampler: Sampler { temperature: 0.9, top_k: 40, greedy: false },
        }));
    }
    let results = engine.run_to_completion(rxs)?;
    let wall = t0.elapsed().as_secs_f64();

    let total_new: usize = results.iter().map(|r| r.tokens.len()).sum();
    let mut queue: Vec<f64> =
        results.iter().map(|r| r.queue_time.as_secs_f64() * 1e3).collect();
    queue.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p = |v: &[f64], q: f64| v[((v.len() - 1) as f64 * q) as usize];

    println!("\n== serving summary ==");
    println!("requests          : {}", results.len());
    println!("lanes             : {}", engine.n_lanes());
    println!("generated tokens  : {total_new}");
    println!("wall time         : {wall:.2}s");
    println!("throughput        : {:.1} tok/s", total_new as f64 / wall);
    println!(
        "queue latency ms  : p50 {:.1}  p90 {:.1}  max {:.1}",
        p(&queue, 0.5),
        p(&queue, 0.9),
        queue.last().unwrap()
    );
    println!(
        "batch occupancy   : {:.2} of {} lanes ({:.2} gen-only)",
        engine.stats()["mean_batch_occupancy"],
        engine.n_lanes(),
        engine.stats()["mean_gen_occupancy"]
    );
    println!(
        "device traffic    : {}",
        engine.transfer_stats().report_per_step(engine.steps_executed)
    );
    // show one generation
    let r0 = &results[0];
    println!(
        "\nsample generation: prompt {:?} -> {:?}",
        &r0.prompt, &r0.tokens
    );

    // === the HTTP frontend over the same trained parameters ===
    // The PJRT client/bundle/engine are not Send, so the driver thread
    // rebuilds them from the (Send) parameter tensors; the accept loop
    // and this demo client run on other threads.
    let vocab = m.model.vocab_size;
    let params = trainer.params()?;
    let listener = std::net::TcpListener::bind(("127.0.0.1", 0))?;
    let addr = listener.local_addr()?;
    println!("\n== HTTP frontend demo (http://{addr}) ==");
    let shutdown = Arc::new(AtomicBool::new(false));
    let server_shutdown = shutdown.clone();
    let server_dir = dir.clone();
    let server_thread = std::thread::spawn(move || {
        let cfg = ServerConfig { vocab: Some(vocab), ..Default::default() };
        server::serve(listener, cfg, server_shutdown, move |driver| {
            let client = Client::cpu()?;
            let manifest = Manifest::load(&server_dir)?;
            let mut names = vec!["step_fwd"];
            if manifest.functions.contains_key("reset_lanes") {
                names.push("reset_lanes");
            }
            let bundle =
                ModelBundle::load_subset(&client, &server_dir, &names)?;
            let mut engine = Engine::new(&bundle, &params, 99)?;
            driver.drive(&mut engine)
        })
    });

    let mut corpus = data::by_name("wikitext", vocab, 31)?;
    for stream in [false, true] {
        let prompt: Vec<Json> = corpus
            .take_vec(6)
            .iter()
            .map(|&t| json::num(t as f64))
            .collect();
        let body = json::obj(vec![
            ("prompt", json::arr(prompt)),
            ("max_tokens", json::num(10.0)),
            ("temperature", json::num(0.9)),
            ("top_k", json::num(40.0)),
            ("stream", Json::Bool(stream)),
        ]);
        let out =
            loadgen::send_completion(&addr, &body, Duration::from_secs(120))?;
        println!(
            "POST /v1/completions stream={stream}: status {} | {} tokens | \
             latency {:.1} ms | ttft {}",
            out.status,
            out.tokens,
            out.latency.as_secs_f64() * 1e3,
            out.ttft
                .map(|t| format!("{:.1} ms", t.as_secs_f64() * 1e3))
                .unwrap_or_else(|| "-".into()),
        );
    }
    let metrics = loadgen::fetch_metrics(&addr)?;
    println!(
        "GET /metrics: scheduler {}",
        metrics.get("scheduler")?.to_string_compact()
    );
    shutdown.store(true, Ordering::SeqCst);
    server_thread
        .join()
        .map_err(|_| sigma_moe::Error::Serving("server panicked".into()))??;
    Ok(())
}
