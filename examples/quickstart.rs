//! Quickstart: load the tiny σ-MoE artifacts, train for a handful of
//! steps on the synthetic WikiText-like corpus, evaluate, and sample a
//! few tokens — the whole public API in ~60 lines.
//!
//!     make artifacts && cargo run --release --example quickstart

use sigma_moe::coordinator::{Metrics, Trainer};
use sigma_moe::data;
use sigma_moe::runtime::{Client, ModelBundle};
use sigma_moe::serving::{Engine, GenRequest, Sampler};
use sigma_moe::Result;

fn main() -> Result<()> {
    let client = Client::cpu()?;
    println!("PJRT platform: {}", client.platform());

    let dir = sigma_moe::artifacts_root().join("tiny-moe");
    let bundle = ModelBundle::load(&client, &dir)?;
    let m = &bundle.manifest;
    println!(
        "model: {} ({} layers, d_model {}, {} experts x G={} with K={})",
        m.preset, m.model.n_layers, m.model.d_model, m.model.n_experts,
        m.model.group_size, m.model.expert_k
    );

    // --- train ---
    let mut trainer = Trainer::new(&bundle, 42)?;
    let mut batcher = data::batcher_for(
        "wikitext", m.model.vocab_size, m.batch_size, m.model.context, 42)?;
    let mut metrics = Metrics::new(m.batch_size * m.model.context);
    trainer.train(&mut batcher, 60, |so| {
        metrics.observe(so).unwrap();
        if so.step % 10 == 0 {
            println!("{}", metrics.report(so));
        }
    })?;

    // --- evaluate with the 4x-context XL memory ---
    let mut eval_batcher = data::batcher_for(
        "wikitext", m.model.vocab_size, m.batch_size, m.model.context, 7)?;
    let ev = trainer.evaluate(&mut eval_batcher, 8)?;
    println!("eval: nll {:.4}  ppl {:.2}", ev.nll, ev.perplexity());

    // --- generate (params() is the explicit device->host sync point) ---
    let mut engine = Engine::new(&bundle, &trainer.params()?, 5)?;
    let mut corpus = data::by_name("wikitext", m.model.vocab_size, 9)?;
    let rx = engine.submit(GenRequest {
        prompt: corpus.take_vec(8),
        max_new_tokens: 16,
        sampler: Sampler::greedy(),
    });
    let out = engine.run_to_completion(vec![rx])?.remove(0);
    println!("generated {} tokens: {:?}", out.tokens.len(), out.tokens);
    Ok(())
}
