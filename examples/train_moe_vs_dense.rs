//! End-to-end driver (the repository's headline validation): train the
//! parameter-matched σ-MoE and dense Transformer-XL on the same
//! synthetic corpus and token budget, log the loss curves, and compare
//! final quality — the paper's Tab. 3 claim at reproduction scale
//! (σ-MoE ≈ dense, at 25% of the MLP FLOPs).
//!
//!     make artifacts && cargo run --release --example train_moe_vs_dense
//!
//! Environment:
//!   STEPS        training steps per model (default 300)
//!   EVAL_SEGS    eval segments (default 24)

use sigma_moe::coordinator::{Metrics, Trainer};
use sigma_moe::data;
use sigma_moe::runtime::{Client, ModelBundle};
use sigma_moe::{flops, Result};

struct RunResult {
    label: &'static str,
    final_train: f64,
    eval_nll: f64,
    ppl: f64,
    tokens_per_sec: f64,
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> Result<()> {
    let steps = env_usize("STEPS", 300);
    let eval_segs = env_usize("EVAL_SEGS", 24);
    let seed = 42u64;
    let client = Client::cpu()?;

    let mut results = Vec::new();
    for (label, preset) in [
        ("dense baseline", "tiny-dense"),
        ("sigma-moe", "tiny-moe"),
    ] {
        let dir = sigma_moe::artifacts_root().join(preset);
        let bundle = ModelBundle::load(&client, &dir)?;
        let m = &bundle.manifest;
        eprintln!(
            "\n=== {label} ({preset}): {} params (analytic), batch {} x ctx {} ===",
            m.flops.get("total_params").copied().unwrap_or(0.0),
            m.batch_size, m.model.context
        );
        let mut trainer = Trainer::new(&bundle, seed as u32)?;
        let mut batcher = data::batcher_for(
            "wikitext", m.model.vocab_size, m.batch_size,
            m.model.context, seed)?;
        let mut eval_batcher = data::batcher_for(
            "wikitext", m.model.vocab_size, m.batch_size,
            m.model.context, seed ^ 0xEBA1)?;
        let csv = format!("loss_curve_{preset}.csv");
        let mut metrics =
            Metrics::new(m.batch_size * m.model.context).with_csv(&csv)?;
        let t0 = std::time::Instant::now();
        trainer.train(&mut batcher, steps, |so| {
            metrics.observe(so).unwrap();
            if (so.step + 1) % 25 == 0 {
                eprintln!("{}", metrics.report(so));
            }
        })?;
        let wall = t0.elapsed().as_secs_f64();
        let ev = trainer.evaluate(&mut eval_batcher, eval_segs)?;
        metrics.flush()?;
        eprintln!("loss curve written to {csv}");
        results.push(RunResult {
            label,
            final_train: metrics.loss_ema.unwrap_or(f64::NAN),
            eval_nll: ev.nll,
            ppl: ev.perplexity(),
            tokens_per_sec: (steps * m.batch_size * m.model.context) as f64
                / wall,
        });
    }

    // the analytic FLOPs fraction that makes the comparison meaningful
    let frac = flops::moe_fraction(128, 16, 32, 4, 516);
    println!("\n== parameter-matched comparison ({steps} steps, same token budget) ==");
    println!(
        "{:<16} {:>12} {:>10} {:>8} {:>12} {:>10}",
        "model", "train-loss", "eval-nll", "ppl", "ff-flops", "tok/s"
    );
    for r in &results {
        let ff = if r.label == "sigma-moe" {
            format!("{:.1}%", 100.0 * frac)
        } else {
            "100.0%".to_string()
        };
        println!(
            "{:<16} {:>12.4} {:>10.4} {:>8.3} {:>12} {:>10.0}",
            r.label, r.final_train, r.eval_nll, r.ppl, ff, r.tokens_per_sec
        );
    }
    let dense = &results[0];
    let moe = &results[1];
    let gap = moe.eval_nll - dense.eval_nll;
    println!(
        "\nσ-MoE vs dense eval-nll gap: {gap:+.4} nats \
         (paper: MoE matches or beats dense at equal params)"
    );
    Ok(())
}
